//! Shard server: the process that owns a slice of every distributed
//! matrix and serves pull/push requests.
//!
//! # Op-dispatch executor
//!
//! The original seed processed every request on one thread per shard
//! (the Akka actor model of the paper: serialized message processing).
//! Requests are now classified by their operation type and dispatched
//! accordingly:
//!
//! - **Read ops** ([`Request::PullRows`], [`Request::PullSparseRows`],
//!   [`Request::PullTopK`], [`Request::PullColSums`],
//!   [`Request::ShardInfo`]) run concurrently on a small reader pool,
//!   each under that matrix's `RwLock` read guard — many pulls against
//!   the same (or different) matrices overlap freely.
//! - **Write ops** (`CreateMatrix`, `GenUid`, `Push*`, `Forget`) stay
//!   serialized on the shard's inbox thread, exactly as before. The
//!   dedup check → apply → record sequence of a push is therefore never
//!   concurrent with another push, preserving the exactly-once
//!   semantics of §2.4 without any per-uid locking; a push briefly
//!   write-locks its matrix to keep readers consistent.
//!
//! # Bounded dedup window
//!
//! Exactly-once pushes are enforced with a seen-uid record: a
//! `PushCoords`/`PushRows` whose uid was already applied acknowledges
//! without re-applying (paper §2.4, Figure 2). The seed kept those
//! records in an unbounded set, so a client that died between its push
//! ack and the `Forget` leaked an entry forever. The record is now a
//! bounded FIFO window ([`PsConfig::dedup_window`]): when full, the
//! oldest un-forgotten uid is evicted and counted, and the eviction
//! total is reported through [`Response::Info`] so operators can see
//! abandoned hand-shakes. An eviction weakens exactly-once only for a
//! push that is retried *after* its record ages out of the window —
//! with the default 65k-entry window and in-flight counts bounded by
//! `pipeline_depth`, that takes tens of thousands of interleaved
//! pushes, far beyond any retry horizon.

use std::collections::{HashMap, HashSet, VecDeque};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::thread::JoinHandle;

use crate::log_warn;
use crate::net::tcp::{TcpServer, TcpTransport};
use crate::net::{respond, Envelope, FaultPlan, Inbox, SimTransport, Transport};
use crate::ps::config::{PsConfig, TransportMode};
use crate::ps::messages::{Data, Dtype, Layout, Request, Response, SparseData};
use crate::ps::partition::Partitioner;
use crate::ps::storage::{DenseShard, SparseShard, StorageElement};
use crate::util::error::{Error, Result};

/// Layout-dispatched storage for one matrix's local slice.
enum Store<T> {
    Dense(DenseShard<T>),
    Sparse(SparseShard<T>),
}

impl<T: StorageElement> Store<T> {
    fn new(layout: Layout, local_rows: u64, cols: u32) -> Store<T> {
        match layout {
            Layout::Dense => Store::Dense(DenseShard::new(local_rows, cols)),
            Layout::Sparse => Store::Sparse(SparseShard::new(local_rows, cols)),
        }
    }

    fn layout(&self) -> Layout {
        match self {
            Store::Dense(_) => Layout::Dense,
            Store::Sparse(_) => Layout::Sparse,
        }
    }

    fn local_rows(&self) -> u64 {
        match self {
            Store::Dense(s) => s.local_rows(),
            Store::Sparse(s) => s.local_rows(),
        }
    }

    fn cols(&self) -> u32 {
        match self {
            Store::Dense(s) => s.cols(),
            Store::Sparse(s) => s.cols(),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            Store::Dense(s) => s.bytes(),
            Store::Sparse(s) => s.bytes(),
        }
    }

    fn read_row(&self, local_row: u64, out: &mut Vec<T>) -> Result<()> {
        match self {
            Store::Dense(s) => s.read_row(local_row, out),
            Store::Sparse(s) => s.read_row(local_row, out),
        }
    }

    fn read_row_sparse(
        &self,
        local_row: u64,
        cols_out: &mut Vec<u32>,
        vals_out: &mut Vec<T>,
    ) -> Result<u32> {
        match self {
            Store::Dense(s) => s.read_row_sparse(local_row, cols_out, vals_out),
            Store::Sparse(s) => s.read_row_sparse(local_row, cols_out, vals_out),
        }
    }

    fn read_row_topk(
        &self,
        local_row: u64,
        k: usize,
        cols_out: &mut Vec<u32>,
        vals_out: &mut Vec<T>,
    ) -> Result<u32> {
        match self {
            Store::Dense(s) => s.read_row_topk(local_row, k, cols_out, vals_out),
            Store::Sparse(s) => s.read_row_topk(local_row, k, cols_out, vals_out),
        }
    }

    fn col_sums(&self, sums: &mut [T]) {
        match self {
            Store::Dense(s) => s.col_sums(sums),
            Store::Sparse(s) => s.col_sums(sums),
        }
    }

    fn add(&mut self, local_row: u64, col: u32, delta: T) -> Result<()> {
        match self {
            Store::Dense(s) => s.add(local_row, col, delta),
            Store::Sparse(s) => s.add(local_row, col, delta),
        }
    }

    fn add_row(&mut self, local_row: u64, deltas: &[T]) -> Result<()> {
        match self {
            Store::Dense(s) => s.add_row(local_row, deltas),
            Store::Sparse(s) => s.add_row(local_row, deltas),
        }
    }
}

/// One matrix's slice on this shard.
enum MatrixSlice {
    I64 { part: Partitioner, store: Store<i64> },
    F32 { part: Partitioner, store: Store<f32> },
}

/// Pull `rows` out of `store` as one dense, concatenated payload.
fn pull_rows_from<T: StorageElement>(
    part: &Partitioner,
    store: &Store<T>,
    rows: &[u64],
) -> Result<Vec<T>> {
    let mut out = Vec::with_capacity(rows.len() * store.cols() as usize);
    for &r in rows {
        store.read_row(part.local_index(r), &mut out)?;
    }
    Ok(out)
}

/// Pull `rows` as `(lens, cols, values)` pair lists; `k = None` returns
/// every non-default pair, `k = Some(n)` the per-row top-n.
fn pull_sparse_from<T: StorageElement>(
    part: &Partitioner,
    store: &Store<T>,
    rows: &[u64],
    k: Option<usize>,
) -> Result<(Vec<u32>, Vec<u32>, Vec<T>)> {
    let mut lens = Vec::with_capacity(rows.len());
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for &r in rows {
        let local = part.local_index(r);
        let n = match k {
            None => store.read_row_sparse(local, &mut cols, &mut vals)?,
            Some(k) => store.read_row_topk(local, k, &mut cols, &mut vals)?,
        };
        lens.push(n);
    }
    Ok((lens, cols, vals))
}

impl MatrixSlice {
    fn local_rows(&self) -> u64 {
        match self {
            MatrixSlice::I64 { store, .. } => store.local_rows(),
            MatrixSlice::F32 { store, .. } => store.local_rows(),
        }
    }

    fn bytes(&self) -> u64 {
        match self {
            MatrixSlice::I64 { store, .. } => store.bytes() as u64,
            MatrixSlice::F32 { store, .. } => store.bytes() as u64,
        }
    }

    fn shape(&self) -> (u64, u32, Dtype, Layout) {
        match self {
            MatrixSlice::I64 { part, store } => {
                (part.rows, store.cols(), Dtype::I64, store.layout())
            }
            MatrixSlice::F32 { part, store } => {
                (part.rows, store.cols(), Dtype::F32, store.layout())
            }
        }
    }

    fn pull_rows(&self, rows: &[u64]) -> Result<Data> {
        match self {
            MatrixSlice::I64 { part, store } => {
                pull_rows_from(part, store, rows).map(Data::I64)
            }
            MatrixSlice::F32 { part, store } => {
                pull_rows_from(part, store, rows).map(Data::F32)
            }
        }
    }

    fn pull_sparse(&self, rows: &[u64], k: Option<usize>) -> Result<SparseData> {
        match self {
            MatrixSlice::I64 { part, store } => {
                let (lens, cols, vals) = pull_sparse_from(part, store, rows, k)?;
                Ok(SparseData { lens, cols, values: Data::I64(vals) })
            }
            MatrixSlice::F32 { part, store } => {
                let (lens, cols, vals) = pull_sparse_from(part, store, rows, k)?;
                Ok(SparseData { lens, cols, values: Data::F32(vals) })
            }
        }
    }

    fn pull_col_sums(&self) -> Data {
        match self {
            MatrixSlice::I64 { store, .. } => {
                let mut sums = vec![0i64; store.cols() as usize];
                store.col_sums(&mut sums);
                Data::I64(sums)
            }
            MatrixSlice::F32 { store, .. } => {
                let mut sums = vec![0f32; store.cols() as usize];
                store.col_sums(&mut sums);
                Data::F32(sums)
            }
        }
    }

    fn apply_coords(&mut self, rows: &[u64], cols: &[u32], values: &Data) -> Result<()> {
        match (self, values) {
            (MatrixSlice::I64 { part, store }, Data::I64(vals)) => {
                for ((&r, &c), &v) in rows.iter().zip(cols).zip(vals) {
                    store.add(part.local_index(r), c, v)?;
                }
                Ok(())
            }
            (MatrixSlice::F32 { part, store }, Data::F32(vals)) => {
                for ((&r, &c), &v) in rows.iter().zip(cols).zip(vals) {
                    store.add(part.local_index(r), c, v)?;
                }
                Ok(())
            }
            _ => Err(Error::PsRejected("dtype mismatch pushing coords".into())),
        }
    }

    fn apply_rows(&mut self, rows: &[u64], values: &Data) -> Result<()> {
        match (self, values) {
            (MatrixSlice::I64 { part, store }, Data::I64(vals)) => {
                let cols = store.cols() as usize;
                if vals.len() != rows.len() * cols {
                    return Err(Error::PsRejected("row push shape mismatch".into()));
                }
                for (&r, chunk) in rows.iter().zip(vals.chunks_exact(cols)) {
                    store.add_row(part.local_index(r), chunk)?;
                }
                Ok(())
            }
            (MatrixSlice::F32 { part, store }, Data::F32(vals)) => {
                let cols = store.cols() as usize;
                if vals.len() != rows.len() * cols {
                    return Err(Error::PsRejected("row push shape mismatch".into()));
                }
                for (&r, chunk) in rows.iter().zip(vals.chunks_exact(cols)) {
                    store.add_row(part.local_index(r), chunk)?;
                }
                Ok(())
            }
            _ => Err(Error::PsRejected("dtype mismatch pushing rows".into())),
        }
    }
}

/// Bounded FIFO record of applied-but-not-forgotten push uids.
struct DedupWindow {
    seen: HashSet<u64>,
    /// Insertion order of un-forgotten uids; may contain stale entries
    /// for uids already forgotten (skipped lazily at eviction time).
    order: VecDeque<u64>,
    /// Maximum `seen` size; `0` means unbounded (the seed's behavior).
    cap: usize,
    evictions: u64,
}

impl DedupWindow {
    fn new(cap: usize) -> DedupWindow {
        DedupWindow { seen: HashSet::new(), order: VecDeque::new(), cap, evictions: 0 }
    }

    fn contains(&self, uid: u64) -> bool {
        self.seen.contains(&uid)
    }

    /// Record an applied uid, evicting the oldest un-forgotten records
    /// once the window overflows.
    fn record(&mut self, uid: u64) {
        if !self.seen.insert(uid) {
            return;
        }
        if self.cap == 0 {
            // Unbounded (the seed's behavior): no eviction order needed.
            return;
        }
        self.order.push_back(uid);
        while self.seen.len() > self.cap {
            match self.order.pop_front() {
                // Stale entries (already forgotten) cost nothing.
                Some(old) => {
                    if self.seen.remove(&old) {
                        self.evictions += 1;
                    }
                }
                None => break,
            }
        }
        // Stale entries (forgotten uids) accumulate in `order` faster
        // than eviction reclaims them in the healthy push→ack→forget
        // workflow (where `seen` never overflows); compact before the
        // queue outgrows the window it serves. Amortized O(1) per push.
        if self.order.len() > self.cap.saturating_mul(2) {
            let seen = &self.seen;
            self.order.retain(|u| seen.contains(u));
        }
    }

    /// Release a uid after the client's ack (phase 3). Its `order`
    /// entry goes stale and is skipped at eviction or compaction time.
    fn forget(&mut self, uid: u64) {
        self.seen.remove(&uid);
    }

    fn pending(&self) -> u64 {
        self.seen.len() as u64
    }
}

/// Shared state of one shard server, lock-partitioned so read ops can
/// run concurrently with each other while pushes stay serialized on the
/// inbox thread.
struct ShardCore {
    shard_id: usize,
    config: PsConfig,
    /// Matrix registry; write-locked only by `CreateMatrix`. Each slice
    /// has its own `RwLock` so pulls of one matrix overlap pushes to
    /// another.
    matrices: RwLock<HashMap<u32, Arc<RwLock<MatrixSlice>>>>,
    dedup: Mutex<DedupWindow>,
    next_uid: AtomicU64,
}

impl ShardCore {
    fn slice(&self, id: u32) -> Result<Arc<RwLock<MatrixSlice>>> {
        self.matrices
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::PsRejected(format!("unknown matrix {id}")))
    }

    /// Handle a read-only operation (safe to run concurrently).
    fn handle_read(&self, req: &Request) -> Response {
        match req {
            Request::PullRows { id, rows } => self
                .slice(*id)
                .and_then(|m| m.read().unwrap().pull_rows(rows))
                .map_or_else(|e| Response::Error(e.to_string()), Response::Rows),
            Request::PullSparseRows { id, rows } => self
                .slice(*id)
                .and_then(|m| m.read().unwrap().pull_sparse(rows, None))
                .map_or_else(|e| Response::Error(e.to_string()), Response::SparseRows),
            Request::PullTopK { id, rows, k } => self
                .slice(*id)
                .and_then(|m| m.read().unwrap().pull_sparse(rows, Some(*k as usize)))
                .map_or_else(|e| Response::Error(e.to_string()), Response::SparseRows),
            Request::PullColSums { id } => self
                .slice(*id)
                .map(|m| m.read().unwrap().pull_col_sums())
                .map_or_else(|e| Response::Error(e.to_string()), Response::Rows),
            Request::ShardInfo => {
                let reg = self.matrices.read().unwrap();
                let (mut local_rows, mut bytes) = (0u64, 0u64);
                for m in reg.values() {
                    let m = m.read().unwrap();
                    local_rows += m.local_rows();
                    bytes += m.bytes();
                }
                let matrices = reg.len() as u32;
                drop(reg);
                let dedup = self.dedup.lock().unwrap();
                Response::Info {
                    shard_id: self.shard_id as u32,
                    shards: self.config.shards as u32,
                    scheme: self.config.scheme,
                    matrices,
                    local_rows,
                    bytes,
                    pending_uids: dedup.pending(),
                    dedup_evictions: dedup.evictions,
                }
            }
            other => Response::Error(format!("not a read op: {other:?}")),
        }
    }

    /// Handle a state-mutating operation. Must be called from a single
    /// thread per shard (the inbox loop): exactly-once dedup relies on
    /// pushes being serialized.
    fn handle_write(&self, req: Request) -> Response {
        match req {
            Request::CreateMatrix { id, rows, cols, dtype, layout } => {
                self.create(id, rows, cols, dtype, layout)
            }
            Request::GenUid => {
                Response::Uid(self.next_uid.fetch_add(1, Ordering::Relaxed) + 1)
            }
            Request::PushCoords { id, uid, rows, cols, values } => {
                if self.dedup.lock().unwrap().contains(uid) {
                    return Response::PushAck { fresh: false };
                }
                if rows.len() != cols.len() || rows.len() != values.len() {
                    return Response::Error(format!(
                        "coord push length mismatch: {} rows, {} cols, {} values",
                        rows.len(),
                        cols.len(),
                        values.len()
                    ));
                }
                let result = self
                    .slice(id)
                    .and_then(|m| m.write().unwrap().apply_coords(&rows, &cols, &values));
                match result {
                    Ok(()) => {
                        self.dedup.lock().unwrap().record(uid);
                        Response::PushAck { fresh: true }
                    }
                    Err(e) => Response::Error(e.to_string()),
                }
            }
            Request::PushRows { id, uid, rows, values } => {
                if self.dedup.lock().unwrap().contains(uid) {
                    return Response::PushAck { fresh: false };
                }
                let result =
                    self.slice(id).and_then(|m| m.write().unwrap().apply_rows(&rows, &values));
                match result {
                    Ok(()) => {
                        self.dedup.lock().unwrap().record(uid);
                        Response::PushAck { fresh: true }
                    }
                    Err(e) => Response::Error(e.to_string()),
                }
            }
            Request::Forget { uid } => {
                self.dedup.lock().unwrap().forget(uid);
                Response::Ok
            }
            Request::Shutdown => Response::Ok,
            other => Response::Error(format!("not a write op: {other:?}")),
        }
    }

    fn create(&self, id: u32, rows: u64, cols: u32, dtype: Dtype, layout: Layout) -> Response {
        let mut reg = self.matrices.write().unwrap();
        // Idempotent: re-creating the same id with the same shape is a
        // no-op (a retried CreateMatrix must not wipe data).
        if let Some(existing) = reg.get(&id) {
            return if existing.read().unwrap().shape() == (rows, cols, dtype, layout) {
                Response::Ok
            } else {
                Response::Error(format!("matrix {id} already exists with different shape"))
            };
        }
        let part = Partitioner::new(rows, self.config.shards, self.config.scheme);
        let local = part.rows_on_shard(self.shard_id);
        let slice = match dtype {
            Dtype::I64 => MatrixSlice::I64 { part, store: Store::new(layout, local, cols) },
            Dtype::F32 => MatrixSlice::F32 { part, store: Store::new(layout, local, cols) },
        };
        reg.insert(id, Arc::new(RwLock::new(slice)));
        Response::Ok
    }
}

/// True for operations that only read shard state and may run on the
/// concurrent reader pool.
fn is_read_op(req: &Request) -> bool {
    matches!(
        req,
        Request::PullRows { .. }
            | Request::PullSparseRows { .. }
            | Request::PullTopK { .. }
            | Request::PullColSums { .. }
            | Request::ShardInfo
    )
}

/// State of one shard server. Cheap handle over the lock-partitioned
/// core; [`ShardState::handle`] processes any request inline (the
/// single-threaded path used by tests and embedded servers), while
/// [`serve`] dispatches reads onto a concurrent pool.
pub struct ShardState {
    core: Arc<ShardCore>,
}

impl ShardState {
    /// Fresh state for shard `shard_id`.
    pub fn new(shard_id: usize, config: PsConfig) -> ShardState {
        let dedup_window = config.dedup_window;
        ShardState {
            core: Arc::new(ShardCore {
                shard_id,
                config,
                matrices: RwLock::new(HashMap::new()),
                dedup: Mutex::new(DedupWindow::new(dedup_window)),
                // Uids carry the shard id in the top bits so they are
                // unique across shards (useful in traces); dedup is
                // per-shard anyway.
                next_uid: AtomicU64::new((shard_id as u64) << 48),
            }),
        }
    }

    /// Handle one decoded request inline.
    pub fn handle(&mut self, req: Request) -> Response {
        if is_read_op(&req) {
            self.core.handle_read(&req)
        } else {
            self.core.handle_write(req)
        }
    }
}

/// Concurrent executor for read ops: a fixed pool of reader threads
/// draining a shared queue. Dropping the pool closes the queue and
/// joins the workers after they finish (and respond to) whatever is
/// still queued.
struct ReadPool {
    tx: Option<mpsc::Sender<(Envelope, Request)>>,
    workers: Vec<JoinHandle<()>>,
}

impl ReadPool {
    fn start(core: Arc<ShardCore>, threads: usize) -> ReadPool {
        let (tx, rx) = mpsc::channel::<(Envelope, Request)>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads.max(1))
            .map(|i| {
                let core = Arc::clone(&core);
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("glint-shard-{}-read-{i}", core.shard_id))
                    .spawn(move || loop {
                        let item = rx.lock().unwrap().recv();
                        match item {
                            Ok((env, req)) => {
                                respond(&env, core.handle_read(&req).encode());
                            }
                            Err(_) => return,
                        }
                    })
                    .expect("spawn shard reader")
            })
            .collect();
        ReadPool { tx: Some(tx), workers }
    }

    fn submit(&self, env: Envelope, req: Request) {
        if let Some(tx) = &self.tx {
            let _ = tx.send((env, req));
        }
    }
}

impl Drop for ReadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Event loop for one shard server thread: write ops inline (serialized
/// — the exactly-once invariant), read ops onto the reader pool.
fn serve(state: ShardState, inbox: Inbox) {
    let readers = ReadPool::start(Arc::clone(&state.core), state.core.config.read_concurrency);
    while let Some(env) = inbox.recv() {
        match Request::decode(&env.payload) {
            Ok(Request::Shutdown) => {
                respond(&env, Response::Ok.encode());
                return; // drops the pool: queued reads drain first
            }
            Ok(req) if is_read_op(&req) => readers.submit(env, req),
            Ok(req) => respond(&env, state.core.handle_write(req).encode()),
            Err(e) => respond(&env, Response::Error(e.to_string()).encode()),
        }
    }
}

/// Spawn one serve-loop thread per inbox, for shards numbered from
/// `first_shard` upward.
fn spawn_serve_threads(
    config: &PsConfig,
    first_shard: usize,
    inboxes: Vec<Inbox>,
) -> Vec<JoinHandle<()>> {
    inboxes
        .into_iter()
        .enumerate()
        .map(|(i, inbox)| {
            let shard_id = first_shard + i;
            let state = ShardState::new(shard_id, config.clone());
            std::thread::Builder::new()
                .name(format!("glint-shard-{shard_id}"))
                .spawn(move || serve(state, inbox))
                .expect("spawn shard server")
        })
        .collect()
}

/// A running group of shard servers plus the transport connecting to
/// them. Owns the server threads; dropping the group shuts them down.
pub struct ServerGroup {
    transport: Arc<dyn Transport>,
    config: PsConfig,
    handles: Vec<JoinHandle<()>>,
    /// Listener handles when the group runs over TCP loopback.
    tcp: Option<TcpServer>,
}

impl ServerGroup {
    /// Start `config.shards` shard servers over the transport selected
    /// by `config.transport`:
    ///
    /// - [`TransportMode::Sim`] — in-process inboxes under `plan`;
    /// - [`TransportMode::TcpLoopback`] — real TCP listeners on
    ///   `127.0.0.1` ephemeral ports (the fault plan does not apply: the
    ///   network itself supplies the at-most-once behavior);
    /// - [`TransportMode::Connect`] — not startable: the servers live in
    ///   other processes (use [`TcpShardServer`] there).
    pub fn start(config: PsConfig, plan: FaultPlan, seed: u64) -> ServerGroup {
        match config.transport {
            TransportMode::Sim => {
                let (transport, inboxes) = SimTransport::new(config.shards, plan, seed);
                let handles = spawn_serve_threads(&config, 0, inboxes);
                ServerGroup { transport: Arc::new(transport), config, handles, tcp: None }
            }
            TransportMode::TcpLoopback => {
                if !plan.is_reliable() {
                    log_warn!(
                        "fault injection is sim-only; the TCP transport ignores the fault plan"
                    );
                }
                let want: Vec<SocketAddr> =
                    vec!["127.0.0.1:0".parse().unwrap(); config.shards];
                let (server, inboxes) =
                    TcpServer::bind(&want).expect("bind loopback tcp listeners");
                let transport = TcpTransport::connect(server.addrs());
                let handles = spawn_serve_threads(&config, 0, inboxes);
                ServerGroup {
                    transport: Arc::new(transport),
                    config,
                    handles,
                    tcp: Some(server),
                }
            }
            TransportMode::Connect(_) => panic!(
                "ServerGroup::start cannot run in Connect mode: the shard servers live in \
                 other processes (run `glint-lda serve` there and connect a client instead)"
            ),
        }
    }

    /// The transport clients should connect through.
    pub fn transport(&self) -> Arc<dyn Transport> {
        Arc::clone(&self.transport)
    }

    /// Deployment config.
    pub fn config(&self) -> &PsConfig {
        &self.config
    }

    /// Gracefully stop all shard threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        for s in 0..self.transport.shards() {
            let ep = self.transport.endpoint(s);
            // Control-plane channel: bypasses fault injection so the stop
            // signal always lands (or errors if the shard already exited).
            let _ = ep
                .send_reliable(Request::Shutdown.encode(), std::time::Duration::from_secs(5));
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        if let Some(mut server) = self.tcp.take() {
            server.shutdown();
        }
    }
}

impl Drop for ServerGroup {
    fn drop(&mut self) {
        if !self.handles.is_empty() {
            self.shutdown_inner();
        }
    }
}

/// Standalone TCP shard servers for multi-process deployments: the
/// `glint-lda serve` half of a `serve` / `train --connect` pair.
///
/// Hosts shards `first_shard .. first_shard + addrs.len()` of a
/// `config.shards`-shard deployment, one listener per shard. Each serve
/// loop exits when it receives a [`Request::Shutdown`] (e.g. from
/// [`crate::ps::client::PsClient::shutdown_servers`]).
pub struct TcpShardServer {
    server: TcpServer,
    handles: Vec<JoinHandle<()>>,
}

impl TcpShardServer {
    /// Bind listeners and start the serve loops. Use port `0` to bind
    /// ephemeral ports and read them back from [`TcpShardServer::addrs`].
    pub fn bind(
        config: PsConfig,
        first_shard: usize,
        addrs: &[SocketAddr],
    ) -> Result<TcpShardServer> {
        if addrs.is_empty() {
            return Err(crate::util::error::Error::Config(
                "serve needs at least one bind address".into(),
            ));
        }
        if first_shard + addrs.len() > config.shards {
            return Err(crate::util::error::Error::Config(format!(
                "shards {first_shard}..{} exceed the {}-shard deployment",
                first_shard + addrs.len(),
                config.shards
            )));
        }
        let (server, inboxes) = TcpServer::bind(addrs)?;
        let handles = spawn_serve_threads(&config, first_shard, inboxes);
        Ok(TcpShardServer { server, handles })
    }

    /// Local listener addresses, in shard order.
    pub fn addrs(&self) -> &[SocketAddr] {
        self.server.addrs()
    }

    /// Block until every hosted shard has been told to shut down, then
    /// stop accepting connections.
    pub fn join(mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.server.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> ShardState {
        // Single shard so every row is local.
        ShardState::new(0, PsConfig::with_shards(1))
    }

    fn create(rows: u64, cols: u32, dtype: Dtype, layout: Layout) -> Request {
        Request::CreateMatrix { id: 1, rows, cols, dtype, layout }
    }

    #[test]
    fn create_pull_push_cycle() {
        for layout in [Layout::Dense, Layout::Sparse] {
            let mut s = state();
            assert_eq!(s.handle(create(4, 3, Dtype::I64, layout)), Response::Ok);
            let uid = match s.handle(Request::GenUid) {
                Response::Uid(u) => u,
                r => panic!("want uid, got {r:?}"),
            };
            assert_eq!(
                s.handle(Request::PushCoords {
                    id: 1,
                    uid,
                    rows: vec![0, 0, 3],
                    cols: vec![0, 1, 2],
                    values: Data::I64(vec![5, 7, -2]),
                }),
                Response::PushAck { fresh: true }
            );
            match s.handle(Request::PullRows { id: 1, rows: vec![0, 3] }) {
                Response::Rows(Data::I64(v)) => assert_eq!(v, vec![5, 7, 0, 0, 0, -2]),
                r => panic!("unexpected {r:?}"),
            }
        }
    }

    #[test]
    fn duplicate_push_not_reapplied() {
        let mut s = state();
        s.handle(create(1, 1, Dtype::I64, Layout::Dense));
        let push = Request::PushCoords {
            id: 1,
            uid: 7,
            rows: vec![0],
            cols: vec![0],
            values: Data::I64(vec![10]),
        };
        assert_eq!(s.handle(push.clone()), Response::PushAck { fresh: true });
        assert_eq!(s.handle(push.clone()), Response::PushAck { fresh: false });
        assert_eq!(s.handle(push), Response::PushAck { fresh: false });
        match s.handle(Request::PullRows { id: 1, rows: vec![0] }) {
            Response::Rows(Data::I64(v)) => assert_eq!(v, vec![10]),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn forget_releases_uid() {
        let mut s = state();
        s.handle(create(1, 1, Dtype::I64, Layout::Dense));
        let push = Request::PushCoords {
            id: 1,
            uid: 9,
            rows: vec![0],
            cols: vec![0],
            values: Data::I64(vec![1]),
        };
        s.handle(push.clone());
        match s.handle(Request::ShardInfo) {
            Response::Info { pending_uids, .. } => assert_eq!(pending_uids, 1),
            r => panic!("unexpected {r:?}"),
        }
        assert_eq!(s.handle(Request::Forget { uid: 9 }), Response::Ok);
        assert_eq!(s.handle(Request::Forget { uid: 9 }), Response::Ok); // idempotent
        match s.handle(Request::ShardInfo) {
            Response::Info { pending_uids, .. } => assert_eq!(pending_uids, 0),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn recreate_same_shape_is_idempotent() {
        let mut s = state();
        let create = create(2, 2, Dtype::I64, Layout::Sparse);
        s.handle(create.clone());
        s.handle(Request::PushCoords {
            id: 1,
            uid: 1,
            rows: vec![1],
            cols: vec![1],
            values: Data::I64(vec![4]),
        });
        // Retried create must not wipe the data.
        assert_eq!(s.handle(create), Response::Ok);
        match s.handle(Request::PullRows { id: 1, rows: vec![1] }) {
            Response::Rows(Data::I64(v)) => assert_eq!(v, vec![0, 4]),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn recreate_different_shape_or_layout_rejected() {
        let mut s = state();
        s.handle(create(2, 2, Dtype::I64, Layout::Dense));
        match s.handle(Request::CreateMatrix {
            id: 1,
            rows: 3,
            cols: 2,
            dtype: Dtype::I64,
            layout: Layout::Dense,
        }) {
            Response::Error(_) => {}
            r => panic!("unexpected {r:?}"),
        }
        match s.handle(Request::CreateMatrix {
            id: 1,
            rows: 2,
            cols: 2,
            dtype: Dtype::I64,
            layout: Layout::Sparse,
        }) {
            Response::Error(_) => {}
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn errors_for_unknown_matrix_and_mismatch() {
        let mut s = state();
        match s.handle(Request::PullRows { id: 99, rows: vec![0] }) {
            Response::Error(m) => assert!(m.contains("unknown")),
            r => panic!("unexpected {r:?}"),
        }
        match s.handle(Request::PullColSums { id: 99 }) {
            Response::Error(m) => assert!(m.contains("unknown")),
            r => panic!("unexpected {r:?}"),
        }
        s.handle(create(1, 1, Dtype::I64, Layout::Dense));
        match s.handle(Request::PushCoords {
            id: 1,
            uid: 1,
            rows: vec![0],
            cols: vec![0],
            values: Data::F32(vec![1.0]),
        }) {
            Response::Error(m) => assert!(m.contains("dtype")),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn failed_push_does_not_consume_uid() {
        let mut s = state();
        s.handle(create(1, 1, Dtype::I64, Layout::Dense));
        // Out-of-bounds column: rejected, uid stays unused, so a corrected
        // retry under the same uid can still apply.
        match s.handle(Request::PushCoords {
            id: 1,
            uid: 5,
            rows: vec![0],
            cols: vec![10],
            values: Data::I64(vec![1]),
        }) {
            Response::Error(_) => {}
            r => panic!("unexpected {r:?}"),
        }
        assert_eq!(
            s.handle(Request::PushCoords {
                id: 1,
                uid: 5,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![1]),
            }),
            Response::PushAck { fresh: true }
        );
    }

    #[test]
    fn sparse_pull_and_topk_and_col_sums() {
        let mut s = state();
        s.handle(create(4, 8, Dtype::I64, Layout::Sparse));
        s.handle(Request::PushCoords {
            id: 1,
            uid: 1,
            rows: vec![0, 0, 2, 2, 2],
            cols: vec![3, 5, 1, 4, 6],
            values: Data::I64(vec![9, 2, 1, 8, 8]),
        });
        match s.handle(Request::PullSparseRows { id: 1, rows: vec![0, 1, 2] }) {
            Response::SparseRows(d) => {
                assert_eq!(d.lens, vec![2, 0, 3]);
                assert_eq!(d.cols, vec![3, 5, 1, 4, 6]);
                assert_eq!(d.values, Data::I64(vec![9, 2, 1, 8, 8]));
            }
            r => panic!("unexpected {r:?}"),
        }
        match s.handle(Request::PullTopK { id: 1, rows: vec![2], k: 2 }) {
            Response::SparseRows(d) => {
                assert_eq!(d.lens, vec![2]);
                // Value ties break by ascending column.
                assert_eq!(d.cols, vec![4, 6]);
                assert_eq!(d.values, Data::I64(vec![8, 8]));
            }
            r => panic!("unexpected {r:?}"),
        }
        match s.handle(Request::PullColSums { id: 1 }) {
            Response::Rows(Data::I64(v)) => {
                assert_eq!(v, vec![0, 1, 0, 9, 8, 2, 8, 0]);
            }
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn dedup_window_evicts_oldest_and_reports() {
        let cfg = PsConfig { dedup_window: 4, ..PsConfig::with_shards(1) };
        let mut s = ShardState::new(0, cfg);
        s.handle(Request::CreateMatrix {
            id: 1,
            rows: 1,
            cols: 1,
            dtype: Dtype::I64,
            layout: Layout::Dense,
        });
        // Six un-forgotten pushes through a 4-entry window: the two
        // oldest records must be evicted.
        for uid in 1..=6u64 {
            let resp = s.handle(Request::PushCoords {
                id: 1,
                uid,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![1]),
            });
            assert_eq!(resp, Response::PushAck { fresh: true });
        }
        match s.handle(Request::ShardInfo) {
            Response::Info { pending_uids, dedup_evictions, .. } => {
                assert_eq!(pending_uids, 4);
                assert_eq!(dedup_evictions, 2);
            }
            r => panic!("unexpected {r:?}"),
        }
        // An evicted uid re-applies (the documented weakening)...
        assert_eq!(
            s.handle(Request::PushCoords {
                id: 1,
                uid: 1,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![1]),
            }),
            Response::PushAck { fresh: true }
        );
        // ...while a uid still inside the window deduplicates.
        assert_eq!(
            s.handle(Request::PushCoords {
                id: 1,
                uid: 6,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![1]),
            }),
            Response::PushAck { fresh: false }
        );
    }

    #[test]
    fn dedup_order_queue_is_compacted_in_healthy_workflow() {
        // Healthy push→ack→forget cycles never overflow `seen`, so the
        // eviction loop alone would let the order queue grow by one
        // entry per push forever; compaction must keep it bounded.
        let mut w = DedupWindow::new(8);
        for uid in 0..10_000u64 {
            assert!(!w.contains(uid));
            w.record(uid);
            w.forget(uid);
        }
        assert!(w.order.len() <= 16, "order queue grew to {}", w.order.len());
        assert_eq!(w.evictions, 0);
        assert_eq!(w.pending(), 0);
    }

    #[test]
    fn forgotten_uids_do_not_count_as_evictions() {
        let cfg = PsConfig { dedup_window: 2, ..PsConfig::with_shards(1) };
        let mut s = ShardState::new(0, cfg);
        s.handle(Request::CreateMatrix {
            id: 1,
            rows: 1,
            cols: 1,
            dtype: Dtype::I64,
            layout: Layout::Dense,
        });
        // Full hand-shakes: push then forget, many times over a tiny
        // window. Nothing is abandoned, so nothing may count as evicted.
        for uid in 1..=10u64 {
            s.handle(Request::PushCoords {
                id: 1,
                uid,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![1]),
            });
            s.handle(Request::Forget { uid });
        }
        match s.handle(Request::ShardInfo) {
            Response::Info { pending_uids, dedup_evictions, .. } => {
                assert_eq!(pending_uids, 0);
                assert_eq!(dedup_evictions, 0);
            }
            r => panic!("unexpected {r:?}"),
        }
    }
}
