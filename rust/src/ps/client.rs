//! Parameter-server client: `BigMatrix` / `BigVector` handles.
//!
//! The user acts on a *virtual view* of a distributed matrix (paper
//! Figure 1): `pull` and `push` take global indices; the client splits
//! each operation per shard (at most one request per shard, §2.3),
//! issues the shard requests concurrently, and hides all delivery
//! machinery:
//!
//! - **pulls** are idempotent, so lost messages are simply retried with
//!   exponential back-off until `max_retries` is exhausted (§2.3);
//! - **pushes** mutate state, so they run the three-phase hand-shake of
//!   §2.4/Figure 2 — `GenUid` (retryable), `Push{uid}` (retried until a
//!   `PushAck`; the shard deduplicates by uid so retries apply at most
//!   once), `Forget{uid}` (retryable) — giving exactly-once effect.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use crate::net::{Endpoint, Transport};
use crate::ps::config::PsConfig;
use crate::ps::messages::{Data, Dtype, Request, Response};
use crate::ps::partition::Partitioner;
use crate::util::error::{Error, Result};

/// Element types storable on the parameter server.
pub trait Element: Copy + Default + Send + Sync + std::fmt::Debug + 'static {
    /// Corresponding wire dtype.
    const DTYPE: Dtype;
    /// Wrap a vector into a typed payload.
    fn wrap(v: Vec<Self>) -> Data;
    /// Unwrap a payload, checking the dtype.
    fn unwrap(d: Data) -> Result<Vec<Self>>;
}

impl Element for i64 {
    const DTYPE: Dtype = Dtype::I64;

    fn wrap(v: Vec<Self>) -> Data {
        Data::I64(v)
    }

    fn unwrap(d: Data) -> Result<Vec<Self>> {
        match d {
            Data::I64(v) => Ok(v),
            other => Err(Error::Decode(format!("expected i64 data, got {:?}", other.dtype()))),
        }
    }
}

impl Element for f32 {
    const DTYPE: Dtype = Dtype::F32;

    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }

    fn unwrap(d: Data) -> Result<Vec<Self>> {
        match d {
            Data::F32(v) => Ok(v),
            other => Err(Error::Decode(format!("expected f32 data, got {:?}", other.dtype()))),
        }
    }
}

/// Client connection to a parameter-server group. Cheap to clone; clones
/// share matrix-id allocation.
#[derive(Clone)]
pub struct PsClient {
    endpoints: Vec<Endpoint>,
    config: PsConfig,
    next_matrix_id: Arc<AtomicU32>,
}

impl PsClient {
    /// Connect through any transport — the simulated in-process network
    /// (from [`crate::ps::server::ServerGroup`]) or a TCP transport
    /// reaching shard servers in other processes.
    pub fn connect(transport: &dyn Transport, config: PsConfig) -> PsClient {
        assert_eq!(
            transport.shards(),
            config.shards,
            "transport endpoint count must match config.shards"
        );
        // Seed matrix ids from wall-clock entropy rather than 1: shard
        // servers keep matrices across client lifetimes (CreateMatrix is
        // idempotent by id + shape), so a fresh client reconnecting to
        // long-running `serve` processes must not silently adopt a
        // previous run's count tables under a recycled id. This is a
        // probabilistic guard (~n_matrices/2^32 per client pair), not a
        // coordination protocol; true multi-tenant isolation would need
        // server-assigned ids agreed across shards.
        let base = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos() ^ (d.as_secs() as u32))
            .unwrap_or(0)
            ^ std::process::id().rotate_left(16);
        PsClient {
            endpoints: transport.endpoints(),
            config,
            next_matrix_id: Arc::new(AtomicU32::new(base.max(1))),
        }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.endpoints.len()
    }

    /// Deployment config.
    pub fn config(&self) -> &PsConfig {
        &self.config
    }

    /// Send `req` to `shard`, retrying with exponential back-off.
    ///
    /// Only safe for idempotent requests (everything except a raw push
    /// without uid — which this API cannot express).
    pub fn request_retry(&self, shard: usize, req: &Request) -> Result<Response> {
        let payload = req.encode();
        let op = match req {
            Request::PullRows { .. } => "pull",
            Request::GenUid => "gen-uid",
            Request::PushCoords { .. } | Request::PushRows { .. } => "push",
            Request::Forget { .. } => "forget",
            Request::CreateMatrix { .. } => "create",
            Request::ShardInfo => "info",
            Request::Shutdown => "shutdown",
        };
        for attempt in 0..self.config.max_retries {
            let timeout = self.config.timeout_for_attempt(attempt);
            if let Ok(bytes) = self.endpoints[shard].request(payload.clone(), timeout) {
                let resp = Response::decode(&bytes)?;
                if let Response::Error(msg) = resp {
                    return Err(Error::PsRejected(msg));
                }
                return Ok(resp);
            }
            // Lost request or lost reply — indistinguishable; retry with a
            // longer timeout (paper §2.3).
        }
        Err(Error::PsTimeout { op, shard, attempts: self.config.max_retries })
    }

    /// Allocate a distributed `rows x cols` matrix.
    pub fn matrix<T: Element>(&self, rows: u64, cols: u32) -> Result<BigMatrix<T>> {
        if rows == 0 || cols == 0 {
            return Err(Error::Config("matrix dimensions must be positive".into()));
        }
        let id = self.next_matrix_id.fetch_add(1, Ordering::SeqCst);
        let req = Request::CreateMatrix { id, rows, cols, dtype: T::DTYPE };
        // Broadcast creation to every shard, in parallel.
        let results: Vec<Result<Response>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..self.shards())
                .map(|s| {
                    let req = &req;
                    scope.spawn(move || self.request_retry(s, req))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("create worker")).collect()
        });
        for r in results {
            r?;
        }
        Ok(BigMatrix {
            client: self.clone(),
            id,
            part: Partitioner::new(rows, self.config.shards, self.config.scheme),
            cols,
            _t: PhantomData,
        })
    }

    /// Allocate a distributed vector of `len` entries (a 1-column matrix).
    pub fn vector<T: Element>(&self, len: u64) -> Result<BigVector<T>> {
        Ok(BigVector { inner: self.matrix(len, 1)? })
    }

    /// Ask every shard server to exit its serve loop. Intended for
    /// externally started `serve` processes once training is done; with
    /// an in-process [`crate::ps::server::ServerGroup`] prefer dropping
    /// the group, which shuts down over the control plane.
    ///
    /// Best-effort: every shard is attempted even when an earlier one
    /// fails (e.g. its ack was lost after it already exited); the first
    /// error is returned afterwards.
    pub fn shutdown_servers(&self) -> Result<()> {
        let mut first_err = None;
        for s in 0..self.shards() {
            let result = match self.request_retry(s, &Request::Shutdown) {
                Ok(Response::Ok) => Ok(()),
                Ok(r) => Err(Error::Decode(format!("unexpected shutdown response {r:?}"))),
                Err(e) => Err(e),
            };
            if let Err(e) = result {
                crate::log_warn!("shutdown of shard {s} failed: {e}");
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Query every shard's info (deployment layout, matrix count,
    /// resident bytes, pending uids).
    pub fn shard_infos(&self) -> Result<Vec<ShardInfo>> {
        (0..self.shards())
            .map(|s| match self.request_retry(s, &Request::ShardInfo)? {
                Response::Info {
                    shard_id,
                    shards,
                    scheme,
                    matrices,
                    local_rows,
                    bytes,
                    pending_uids,
                } => Ok(ShardInfo {
                    shard_id,
                    shards,
                    scheme,
                    matrices,
                    local_rows,
                    bytes,
                    pending_uids,
                }),
                r => Err(Error::Decode(format!("unexpected info response {r:?}"))),
            })
            .collect()
    }

    /// Verify this client's deployment view against what every shard
    /// server reports: address order must match shard ids, and shard
    /// count and partitioning scheme must agree — otherwise pushes and
    /// pulls would silently land on the wrong rows. Essential before
    /// training over `--connect`.
    pub fn validate_deployment(&self) -> Result<()> {
        for (expect, info) in self.shard_infos()?.into_iter().enumerate() {
            if info.shard_id as usize != expect {
                return Err(Error::Config(format!(
                    "endpoint {expect} is shard {} — the connect address list is out of order",
                    info.shard_id
                )));
            }
            if info.shards as usize != self.config.shards {
                return Err(Error::Config(format!(
                    "server reports a {}-shard deployment but this client connects {} \
                     endpoint(s); row partitioning would disagree",
                    info.shards,
                    self.config.shards
                )));
            }
            if info.scheme != self.config.scheme {
                return Err(Error::Config(format!(
                    "server partitions rows with the {:?} scheme, client is configured \
                     for {:?}",
                    info.scheme, self.config.scheme
                )));
            }
        }
        Ok(())
    }
}

/// One shard server's introspection report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardInfo {
    /// The server's global shard id.
    pub shard_id: u32,
    /// Total shards in the server's deployment.
    pub shards: u32,
    /// Row partitioning scheme on the server.
    pub scheme: crate::ps::partition::PartitionScheme,
    /// Matrices hosted.
    pub matrices: u32,
    /// Total local rows across matrices.
    pub local_rows: u64,
    /// Payload bytes resident.
    pub bytes: u64,
    /// Outstanding (un-forgotten) push uids.
    pub pending_uids: u64,
}

/// Sparse additive deltas destined for one matrix, grouped per shard by
/// the client before pushing.
#[derive(Debug, Clone, Default)]
pub struct CoordDeltas<T> {
    /// Global rows.
    pub rows: Vec<u64>,
    /// Columns.
    pub cols: Vec<u32>,
    /// Delta values.
    pub values: Vec<T>,
}

impl<T> CoordDeltas<T> {
    /// Number of deltas.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no deltas.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Handle to a distributed `rows x cols` matrix of `T`.
///
/// The handle is clonable and thread-safe; concurrent pushes from many
/// workers are the intended use (the counts are commutative).
#[derive(Clone)]
pub struct BigMatrix<T: Element> {
    client: PsClient,
    id: u32,
    part: Partitioner,
    cols: u32,
    _t: PhantomData<T>,
}

impl<T: Element> BigMatrix<T> {
    /// Global rows.
    pub fn rows(&self) -> u64 {
        self.part.rows
    }

    /// Columns.
    pub fn cols(&self) -> u32 {
        self.cols
    }

    /// Matrix id (diagnostics).
    pub fn id(&self) -> u32 {
        self.id
    }

    /// Pull full rows by global index; returns values row-major in the
    /// order requested (`rows.len() * cols` entries).
    pub fn pull_rows(&self, rows: &[u64]) -> Result<Vec<T>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        for &r in rows {
            if r >= self.part.rows {
                return Err(Error::Config(format!(
                    "row {r} out of bounds ({} rows)",
                    self.part.rows
                )));
            }
        }
        // Split into at most one request per shard (§2.3).
        let mut per_shard: Vec<Vec<u64>> = vec![Vec::new(); self.client.shards()];
        for &r in rows {
            per_shard[self.part.shard_of(r)].push(r);
        }
        // Issue shard requests concurrently; each retries independently.
        let shard_results: Vec<Result<Vec<T>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = per_shard
                .iter()
                .enumerate()
                .map(|(s, shard_rows)| {
                    scope.spawn(move || -> Result<Vec<T>> {
                        if shard_rows.is_empty() {
                            return Ok(Vec::new());
                        }
                        let req = Request::PullRows { id: self.id, rows: shard_rows.clone() };
                        match self.client.request_retry(s, &req)? {
                            Response::Rows(data) => T::unwrap(data),
                            r => Err(Error::Decode(format!("unexpected pull response {r:?}"))),
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("pull worker")).collect()
        });
        // Scatter back into request order.
        let cols = self.cols as usize;
        let mut shard_data = Vec::with_capacity(shard_results.len());
        for r in shard_results {
            shard_data.push(r?);
        }
        let mut cursor = vec![0usize; self.client.shards()];
        let mut out = vec![T::default(); rows.len() * cols];
        for (i, &r) in rows.iter().enumerate() {
            let s = self.part.shard_of(r);
            let src = &shard_data[s][cursor[s]..cursor[s] + cols];
            out[i * cols..(i + 1) * cols].copy_from_slice(src);
            cursor[s] += cols;
        }
        Ok(out)
    }

    /// Pull a single row.
    pub fn pull_row(&self, row: u64) -> Result<Vec<T>> {
        self.pull_rows(&[row])
    }

    /// Push sparse additive deltas with exactly-once semantics.
    ///
    /// Deltas are grouped per shard; each shard group runs the hand-shake
    /// independently and concurrently.
    pub fn push_coords(&self, deltas: &CoordDeltas<T>) -> Result<()> {
        if deltas.is_empty() {
            return Ok(());
        }
        if deltas.rows.len() != deltas.cols.len() || deltas.rows.len() != deltas.values.len() {
            return Err(Error::Config("delta arrays must have equal length".into()));
        }
        let mut per_shard: Vec<CoordDeltas<T>> =
            (0..self.client.shards()).map(|_| CoordDeltas::default()).collect();
        for ((&r, &c), &v) in deltas.rows.iter().zip(&deltas.cols).zip(&deltas.values) {
            if r >= self.part.rows || c >= self.cols {
                return Err(Error::Config(format!(
                    "delta ({r},{c}) out of bounds for {}x{}",
                    self.part.rows, self.cols
                )));
            }
            let s = self.part.shard_of(r);
            per_shard[s].rows.push(r);
            per_shard[s].cols.push(c);
            per_shard[s].values.push(v);
        }
        let results: Vec<Result<()>> = std::thread::scope(|scope| {
            let handles: Vec<_> = per_shard
                .iter()
                .enumerate()
                .map(|(s, group)| {
                    scope.spawn(move || -> Result<()> {
                        if group.is_empty() {
                            return Ok(());
                        }
                        self.handshake_push(s, |uid| Request::PushCoords {
                            id: self.id,
                            uid,
                            rows: group.rows.clone(),
                            cols: group.cols.clone(),
                            values: T::wrap(group.values.clone()),
                        })
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("push worker")).collect()
        });
        for r in results {
            r?;
        }
        Ok(())
    }

    /// Push dense full-row deltas (`rows.len() * cols` values, row-major)
    /// with exactly-once semantics.
    pub fn push_rows(&self, rows: &[u64], values: &[T]) -> Result<()> {
        if rows.is_empty() {
            return Ok(());
        }
        let cols = self.cols as usize;
        if values.len() != rows.len() * cols {
            return Err(Error::Config(format!(
                "push_rows shape mismatch: {} values for {} rows x {} cols",
                values.len(),
                rows.len(),
                cols
            )));
        }
        let shards = self.client.shards();
        let mut shard_rows: Vec<Vec<u64>> = vec![Vec::new(); shards];
        let mut shard_vals: Vec<Vec<T>> = vec![Vec::new(); shards];
        for (i, &r) in rows.iter().enumerate() {
            if r >= self.part.rows {
                return Err(Error::Config(format!("row {r} out of bounds")));
            }
            let s = self.part.shard_of(r);
            shard_rows[s].push(r);
            shard_vals[s].extend_from_slice(&values[i * cols..(i + 1) * cols]);
        }
        let results: Vec<Result<()>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..shards)
                .map(|s| {
                    let rws = &shard_rows[s];
                    let vls = &shard_vals[s];
                    scope.spawn(move || -> Result<()> {
                        if rws.is_empty() {
                            return Ok(());
                        }
                        self.handshake_push(s, |uid| Request::PushRows {
                            id: self.id,
                            uid,
                            rows: rws.clone(),
                            values: T::wrap(vls.clone()),
                        })
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("push worker")).collect()
        });
        for r in results {
            r?;
        }
        Ok(())
    }

    /// The §2.4 hand-shake against one shard: acquire uid, push until
    /// acknowledged, then release the uid.
    fn handshake_push(&self, shard: usize, make: impl Fn(u64) -> Request) -> Result<()> {
        // Phase 1: unique id (safe to retry: ids are cheap and unused ids
        // are never recorded).
        let uid = match self.client.request_retry(shard, &Request::GenUid)? {
            Response::Uid(u) => u,
            r => return Err(Error::Decode(format!("unexpected gen-uid response {r:?}"))),
        };
        // Phase 2: push, retried until *some* ack arrives. The shard
        // applies the uid at most once, so duplicates are harmless.
        let push = make(uid);
        match self.client.request_retry(shard, &push)? {
            Response::PushAck { .. } => {}
            r => return Err(Error::Decode(format!("unexpected push response {r:?}"))),
        }
        // Phase 3: release the dedup record. Idempotent.
        match self.client.request_retry(shard, &Request::Forget { uid })? {
            Response::Ok => Ok(()),
            r => Err(Error::Decode(format!("unexpected forget response {r:?}"))),
        }
    }
}

/// Handle to a distributed vector (1-column matrix).
#[derive(Clone)]
pub struct BigVector<T: Element> {
    inner: BigMatrix<T>,
}

impl<T: Element> BigVector<T> {
    /// Length.
    pub fn len(&self) -> u64 {
        self.inner.rows()
    }

    /// Always false (vectors are non-empty by construction).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pull selected entries.
    pub fn pull(&self, indices: &[u64]) -> Result<Vec<T>> {
        self.inner.pull_rows(indices)
    }

    /// Pull the entire vector.
    pub fn pull_all(&self) -> Result<Vec<T>> {
        let indices: Vec<u64> = (0..self.len()).collect();
        self.pull(&indices)
    }

    /// Push sparse additive deltas.
    pub fn push(&self, indices: &[u64], deltas: &[T]) -> Result<()> {
        let cd = CoordDeltas {
            rows: indices.to_vec(),
            cols: vec![0; indices.len()],
            values: deltas.to_vec(),
        };
        self.inner.push_coords(&cd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::FaultPlan;
    use crate::ps::server::ServerGroup;

    fn setup(shards: usize, plan: FaultPlan) -> (ServerGroup, PsClient) {
        let cfg = PsConfig::with_shards(shards);
        let group = ServerGroup::start(cfg.clone(), plan, 42);
        let client = PsClient::connect(&group.transport(), cfg);
        (group, client)
    }

    #[test]
    fn matrix_pull_initially_zero() {
        let (_g, client) = setup(3, FaultPlan::reliable());
        let m: BigMatrix<i64> = client.matrix(10, 4).unwrap();
        let vals = m.pull_rows(&[0, 3, 9]).unwrap();
        assert_eq!(vals, vec![0; 12]);
    }

    #[test]
    fn push_then_pull_roundtrip() {
        let (_g, client) = setup(4, FaultPlan::reliable());
        let m: BigMatrix<i64> = client.matrix(100, 5).unwrap();
        let deltas = CoordDeltas {
            rows: vec![0, 1, 50, 99, 0],
            cols: vec![0, 1, 2, 4, 0],
            values: vec![3, -1, 7, 2, 4],
        };
        m.push_coords(&deltas).unwrap();
        let vals = m.pull_rows(&[0, 1, 50, 99]).unwrap();
        assert_eq!(vals[0], 7); // 3 + 4 accumulated
        assert_eq!(vals[5 + 1], -1);
        assert_eq!(vals[10 + 2], 7);
        assert_eq!(vals[15 + 4], 2);
    }

    #[test]
    fn push_rows_dense() {
        let (_g, client) = setup(2, FaultPlan::reliable());
        let m: BigMatrix<f32> = client.matrix(4, 3).unwrap();
        m.push_rows(&[1, 2], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        m.push_rows(&[1], &[0.5, 0.5, 0.5]).unwrap();
        let vals = m.pull_rows(&[1, 2]).unwrap();
        assert_eq!(vals, vec![1.5, 2.5, 3.5, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn vector_ops() {
        let (_g, client) = setup(3, FaultPlan::reliable());
        let v: BigVector<i64> = client.vector(7).unwrap();
        v.push(&[0, 6, 0], &[5, 10, 1]).unwrap();
        assert_eq!(v.pull_all().unwrap(), vec![6, 0, 0, 0, 0, 0, 10]);
    }

    #[test]
    fn out_of_bounds_rejected_client_side() {
        let (_g, client) = setup(2, FaultPlan::reliable());
        let m: BigMatrix<i64> = client.matrix(5, 2).unwrap();
        assert!(m.pull_rows(&[5]).is_err());
        let bad = CoordDeltas { rows: vec![0], cols: vec![9], values: vec![1] };
        assert!(m.push_coords(&bad).is_err());
    }

    #[test]
    fn exactly_once_under_lossy_network() {
        // 20% request loss, 20% reply loss, 10% duplication: the sum of
        // all deltas must still be applied exactly once each.
        let (_g, client) = setup(3, FaultPlan::lossy(0.2, 0.1));
        let m: BigMatrix<i64> = client.matrix(30, 2).unwrap();
        let mut expect = vec![0i64; 30 * 2];
        for round in 0..20 {
            let deltas = CoordDeltas {
                rows: vec![round % 30, (round * 7) % 30],
                cols: vec![0, 1],
                values: vec![1, 2],
            };
            expect[(deltas.rows[0] * 2) as usize] += 1;
            expect[(deltas.rows[1] * 2 + 1) as usize] += 2;
            m.push_coords(&deltas).unwrap();
        }
        let all: Vec<u64> = (0..30).collect();
        let got = m.pull_rows(&all).unwrap();
        assert_eq!(got, expect);
    }

    #[test]
    fn concurrent_pushers_accumulate() {
        let (_g, client) = setup(4, FaultPlan::reliable());
        let m: BigMatrix<i64> = client.matrix(16, 1).unwrap();
        std::thread::scope(|scope| {
            for t in 0..8 {
                let m = m.clone();
                scope.spawn(move || {
                    for i in 0..50 {
                        let deltas = CoordDeltas {
                            rows: vec![((t * 50 + i) % 16) as u64],
                            cols: vec![0],
                            values: vec![1],
                        };
                        m.push_coords(&deltas).unwrap();
                    }
                });
            }
        });
        let all: Vec<u64> = (0..16).collect();
        let got = m.pull_rows(&all).unwrap();
        assert_eq!(got.iter().sum::<i64>(), 8 * 50);
    }

    #[test]
    fn total_loss_times_out_with_error() {
        let cfg = PsConfig {
            shards: 1,
            max_retries: 3,
            timeout: std::time::Duration::from_millis(5),
            ..PsConfig::default()
        };
        let group = ServerGroup::start(
            cfg.clone(),
            FaultPlan { drop_request: 1.0, ..FaultPlan::default() },
            7,
        );
        let client = PsClient::connect(&group.transport(), cfg);
        match client.matrix::<i64>(4, 1) {
            Err(Error::PsTimeout { attempts, .. }) => assert_eq!(attempts, 3),
            Err(e) => panic!("unexpected error {e}"),
            Ok(_) => panic!("matrix creation should have timed out"),
        }
    }
}
