//! Parameter-server client: `BigMatrix` / `BigVector` handles with an
//! asynchronous, ticket-based operation API.
//!
//! The user acts on a *virtual view* of a distributed matrix (paper
//! Figure 1): `pull` and `push` take global indices; the client splits
//! each operation per shard (at most one request per shard, §2.3),
//! issues the shard requests concurrently, and hides all delivery
//! machinery:
//!
//! - **pulls** are idempotent, so lost messages are simply retried with
//!   exponential back-off until `max_retries` is exhausted (§2.3);
//! - **pushes** mutate state, so they run the three-phase hand-shake of
//!   §2.4/Figure 2 — `GenUid` (retryable), `Push{uid}` (retried until a
//!   `PushAck`; the shard deduplicates by uid so retries apply at most
//!   once), `Forget{uid}` (retryable) — giving exactly-once effect.
//!
//! # Asynchronous tickets
//!
//! Every operation has an `_async` variant returning a [`Ticket`]
//! immediately; the operation runs on per-shard client worker threads.
//! The ticket is generic over its result — `Ticket<Vec<T>>` for dense
//! pulls and column sums, `Ticket<Vec<SparseRow<T>>>` for sparse and
//! top-k pulls, `Ticket<()>` for pushes — with one [`Ticket::wait`]
//! contract for all of them. Each shard has a **bounded in-flight
//! window** ([`PsConfig::pipeline_depth`]): at most that many
//! operations may be outstanding against a shard, and further
//! submissions block, giving natural backpressure. The blocking methods
//! (`pull_rows`, `push_coords`, …) are thin `_async` + [`Ticket::wait`]
//! wrappers.
//!
//! # Ordering guarantees
//!
//! - **Per ticket, exactly-once.** A push `Ticket<()>` that resolves
//!   `Ok` means every shard applied its deltas exactly once, regardless
//!   of message loss, duplication, or retries underneath.
//! - **No cross-ticket ordering.** Two tickets issued back-to-back may
//!   execute against a shard in either order (the window is a pool, not
//!   a queue of one). This is safe for the counter workloads the server
//!   hosts — additive deltas commute — but code that needs
//!   happens-before between two operations must `wait()` the first or
//!   call [`PsClient::flush`] between them.
//! - **`flush` is the barrier.** [`PsClient::flush`] (also reachable as
//!   [`BigMatrix::flush`] / [`BigVector::flush`]) blocks until every
//!   operation submitted *before* the call has completed on every
//!   shard, then reports the first error of any fire-and-forget push
//!   whose ticket was dropped. Pulls issued after a `flush` observe all
//!   pushes submitted before it. Call it before perplexity evaluation,
//!   checkpointing, or reading your own writes.
//! - **Dropped tickets are fire-and-forget, not cancelled.** The
//!   operation still runs to completion; a dropped push ticket's
//!   error is parked and surfaced by the next `flush`.
//!
//! # Replica failover
//!
//! When [`PsConfig::backups`] lists `k * shards` backup addresses
//! (tier-major), each shard's requests travel through a shared route
//! `[primary, tier1, ..., tierk]`: deliveries go to the route's
//! *active* replica, and after [`PsConfig::failover_after`]
//! consecutive failures (timeouts, or `Unavailable` answers from a
//! gated replica) the route advances to the next one and keeps
//! retrying there, with `Unavailable` retries paced by a jittered
//! [`PsConfig::unavailable_pause`]. The route is shared by every clone
//! of the client, so one courier discovering a dead primary moves the
//! whole client. The cluster coordinator completes the switch by
//! promoting the first live backup on the chain
//! ([`PsClient::promote_backup`]), can attach a fresh standby behind
//! the new head mid-run ([`PsClient::reseed_backup`]), and can retire
//! a healthy head without losing its commit window
//! ([`PsClient::drain_shard`]).

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::net::stats::EndpointStats;
use crate::net::{Endpoint, Transport};
use crate::ps::config::PsConfig;
use crate::ps::messages::{Data, Dtype, Layout, Request, Response};
use crate::ps::partition::Partitioner;
use crate::util::error::{Error, Result};

/// Element types storable on the parameter server.
pub trait Element:
    Copy
    + Default
    + Send
    + Sync
    + std::fmt::Debug
    + PartialEq
    + PartialOrd
    + std::ops::AddAssign
    + 'static
{
    /// Corresponding wire dtype.
    const DTYPE: Dtype;
    /// Wrap a vector into a typed payload.
    fn wrap(v: Vec<Self>) -> Data;
    /// Unwrap a payload, checking the dtype.
    fn unwrap(d: Data) -> Result<Vec<Self>>;
}

impl Element for i64 {
    const DTYPE: Dtype = Dtype::I64;

    fn wrap(v: Vec<Self>) -> Data {
        Data::I64(v)
    }

    fn unwrap(d: Data) -> Result<Vec<Self>> {
        match d {
            Data::I64(v) => Ok(v),
            other => Err(Error::Decode(format!("expected i64 data, got {:?}", other.dtype()))),
        }
    }
}

impl Element for f32 {
    const DTYPE: Dtype = Dtype::F32;

    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }

    fn unwrap(d: Data) -> Result<Vec<Self>> {
        match d {
            Data::F32(v) => Ok(v),
            other => Err(Error::Decode(format!("expected f32 data, got {:?}", other.dtype()))),
        }
    }
}

/// An asynchronous operation executed on a shard dispatcher worker.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// One shard's replica set: the primary endpoint first, then the
/// replica chain tier by tier. Requests go to the `active` replica;
/// repeated failures advance it (round-robin). Shared — via `Arc` — by
/// every courier and clone of the client, so whichever courier trips
/// the threshold fails the whole client over at once.
struct ShardRoute {
    eps: Vec<Endpoint>,
    active: AtomicUsize,
    /// Consecutive failures against the active replica.
    fails: AtomicUsize,
    /// Consecutive-failure threshold before the route advances
    /// ([`PsConfig::failover_after`]).
    failover_after: usize,
    /// Resolved seed of this route's retry-pause jitter stream.
    jitter_seed: u64,
    /// Jitter draws so far — each draw forks its own stream off the
    /// seed, so the sequence is deterministic yet never repeats.
    jitter_draws: AtomicU64,
    /// Retries provoked by `Unavailable` answers (gated replicas).
    /// Drain and promotion demos assert this stays bounded — a planned
    /// hand-off must not degenerate into a retry storm.
    unavailable_retries: AtomicU64,
}

impl ShardRoute {
    fn new(eps: Vec<Endpoint>, failover_after: usize, jitter_seed: u64) -> ShardRoute {
        assert!(!eps.is_empty());
        ShardRoute {
            eps,
            active: AtomicUsize::new(0),
            fails: AtomicUsize::new(0),
            failover_after: failover_after.max(1),
            jitter_seed,
            jitter_draws: AtomicU64::new(0),
            unavailable_retries: AtomicU64::new(0),
        }
    }

    /// Index of the replica currently serving this shard.
    fn active(&self) -> usize {
        self.active.load(Ordering::Relaxed) % self.eps.len()
    }

    /// The endpoint requests should go to right now.
    fn endpoint(&self) -> &Endpoint {
        &self.eps[self.active()]
    }

    /// A delivery succeeded: the active replica is healthy.
    fn record_success(&self) {
        self.fails.store(0, Ordering::Relaxed);
    }

    /// A delivery failed (timeout or gated replica). After
    /// `failover_after` consecutive failures the route advances to
    /// the next replica; with a single replica there is nowhere to go.
    fn record_failure(&self, shard: usize) {
        if self.eps.len() < 2 {
            return;
        }
        if self.fails.fetch_add(1, Ordering::Relaxed) + 1 < self.failover_after {
            return;
        }
        self.fails.store(0, Ordering::Relaxed);
        let from = self.active();
        let to = (from + 1) % self.eps.len();
        self.active.store(to, Ordering::Relaxed);
        // Account the event against the shard's primary stats object so
        // per-shard counters stay in one place regardless of direction.
        self.eps[0].stats.record_failover();
        crate::log_warn!("shard {shard}: replica {from} unresponsive, failing over to {to}");
    }

    /// Pin the route to replica `idx` (coordinator-driven promotion).
    fn force(&self, idx: usize) {
        self.fails.store(0, Ordering::Relaxed);
        self.active.store(idx % self.eps.len(), Ordering::Relaxed);
    }

    /// Jittered pause in `[base/2, 3*base/2)` before retrying a gated
    /// replica, counting the retry. Burning the full back-off ladder on
    /// an alive-but-gated replica would only delay a promotion from
    /// taking effect; retrying on a fixed pause would stampede it in
    /// lockstep across a fleet of couriers. Deterministic per route for
    /// a fixed [`PsConfig::retry_jitter_seed`].
    fn unavailable_pause(&self, base: Duration) -> Duration {
        self.unavailable_retries.fetch_add(1, Ordering::Relaxed);
        let n = self.jitter_draws.fetch_add(1, Ordering::Relaxed);
        let mut rng = crate::util::rng::Pcg64::new(
            self.jitter_seed.wrapping_mul(0x9e37_79b9).wrapping_add(n),
        );
        let us = base.as_micros().max(1) as u64;
        Duration::from_micros(us / 2 + rng.next_u64() % us)
    }

    /// Retries provoked by `Unavailable` answers on this route so far.
    fn unavailable_retry_count(&self) -> u64 {
        self.unavailable_retries.load(Ordering::Relaxed)
    }
}

/// Per-shard delivery agent: the shard's replica route plus the retry
/// configuration, and nothing else — cheap to clone into asynchronous
/// jobs without keeping the whole client (and its dispatcher threads)
/// alive from inside their own queue.
#[derive(Clone)]
struct Courier {
    route: Arc<ShardRoute>,
    shard: usize,
    config: PsConfig,
}

impl Courier {
    /// Send `req` to this courier's shard, retrying with exponential
    /// back-off and failing over between replicas.
    ///
    /// Only safe for idempotent requests (everything except a raw push
    /// without uid — which this API cannot express).
    fn request_retry(&self, req: &Request) -> Result<Response> {
        let payload = req.encode();
        let op = match req {
            Request::PullRows { .. } => "pull",
            Request::PullSparseRows { .. } => "pull-sparse",
            Request::PullTopK { .. } => "pull-topk",
            Request::PullColSums { .. } => "pull-col-sums",
            Request::GenUid => "gen-uid",
            Request::PushCoords { .. } | Request::PushRows { .. } => "push",
            Request::Forget { .. } => "forget",
            Request::CreateMatrix { .. } => "create",
            Request::DeleteMatrix { .. } => "delete-matrix",
            Request::ShardInfo => "info",
            Request::ReplPoll { .. } => "repl-poll",
            Request::Promote => "promote",
            Request::ReplApply { .. } => "repl-apply",
            Request::ReplSeed { .. } => "repl-seed",
            Request::Drain => "drain",
            Request::Shutdown => "shutdown",
        };
        for attempt in 0..self.config.max_retries {
            let timeout = self.config.timeout_for_attempt(attempt);
            match self.route.endpoint().request(payload.clone(), timeout) {
                Ok(bytes) => match Response::decode(&bytes)? {
                    Response::Error(msg) => {
                        // The replica answered: it is healthy, the
                        // request is what it rejects.
                        self.route.record_success();
                        return Err(Error::PsRejected(msg));
                    }
                    Response::Unavailable(_) => {
                        // Alive but gated (un-promoted backup, draining
                        // head): counts toward failover, retried after a
                        // short jittered pause rather than the full
                        // back-off step.
                        self.route.record_failure(self.shard);
                        std::thread::sleep(
                            self.route
                                .unavailable_pause(timeout.min(self.config.unavailable_pause)),
                        );
                    }
                    resp => {
                        self.route.record_success();
                        return Ok(resp);
                    }
                },
                // Lost request or lost reply — indistinguishable; retry
                // with a longer timeout (paper §2.3).
                Err(()) => self.route.record_failure(self.shard),
            }
        }
        Err(Error::PsTimeout { op, shard: self.shard, attempts: self.config.max_retries })
    }

    /// The §2.4 hand-shake against this shard: acquire uid, push until
    /// acknowledged, then release the uid.
    fn handshake_push(&self, make: impl Fn(u64) -> Request) -> Result<()> {
        // Phase 1: unique id (safe to retry: ids are cheap and unused ids
        // are never recorded).
        let uid = match self.request_retry(&Request::GenUid)? {
            Response::Uid(u) => u,
            r => return Err(Error::Decode(format!("unexpected gen-uid response {r:?}"))),
        };
        // Phase 2: push, retried until *some* ack arrives. The shard
        // applies the uid at most once, so duplicates are harmless.
        let push = make(uid);
        match self.request_retry(&push)? {
            Response::PushAck { .. } => {}
            r => return Err(Error::Decode(format!("unexpected push response {r:?}"))),
        }
        // Phase 3: release the dedup record. Idempotent.
        match self.request_retry(&Request::Forget { uid })? {
            Response::Ok => Ok(()),
            r => Err(Error::Decode(format!("unexpected forget response {r:?}"))),
        }
    }
}

/// State behind one shard's dispatch window.
struct DispatcherState {
    queue: VecDeque<QueuedJob>,
    /// Sequence numbers of submitted-but-not-completed ops. Bounded by
    /// the window depth, so the set stays tiny; its minimum drives the
    /// flush barrier's "everything submitted before my snapshot"
    /// semantics.
    outstanding: std::collections::BTreeSet<u64>,
    /// Sequence number the next submission will take.
    next_seq: u64,
    shutdown: bool,
}

struct QueuedJob {
    job: Job,
    seq: u64,
    queued_at: Instant,
}

struct DispatcherShared {
    state: Mutex<DispatcherState>,
    /// Workers wait here for jobs.
    available: Condvar,
    /// Submitters wait here for window room; `flush` waits here for its
    /// snapshot of outstanding ops to complete.
    room: Condvar,
    depth: usize,
    stats: Arc<EndpointStats>,
}

/// One shard's bounded in-flight window: `depth` worker threads drain a
/// queue whose total outstanding (queued + executing) count is capped at
/// `depth`, so submission backpressures the producers.
struct ShardDispatcher {
    shared: Arc<DispatcherShared>,
    workers: Vec<JoinHandle<()>>,
}

impl ShardDispatcher {
    fn start(shard: usize, depth: usize, stats: Arc<EndpointStats>) -> ShardDispatcher {
        let depth = depth.max(1);
        let shared = Arc::new(DispatcherShared {
            state: Mutex::new(DispatcherState {
                queue: VecDeque::new(),
                outstanding: std::collections::BTreeSet::new(),
                next_seq: 0,
                shutdown: false,
            }),
            available: Condvar::new(),
            room: Condvar::new(),
            depth,
            stats,
        });
        let workers = (0..depth)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("glint-ps-dispatch-{shard}-{i}"))
                    .spawn(move || dispatcher_worker(&shared))
                    // PANIC-OK: dispatcher spawn fails only on resource
                    // exhaustion while the client connects.
                    .expect("spawn ps dispatcher worker")
            })
            .collect();
        ShardDispatcher { shared, workers }
    }

    /// Enqueue `job`, blocking while this shard's window is full.
    fn submit(&self, job: Job) {
        let mut st = self.shared.state.lock().unwrap();
        while st.outstanding.len() >= self.shared.depth {
            st = self.shared.room.wait(st).unwrap();
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.outstanding.insert(seq);
        st.queue.push_back(QueuedJob { job, seq, queued_at: Instant::now() });
        self.shared.stats.record_op_submitted();
        drop(st);
        self.shared.available.notify_one();
    }

    /// This shard's submission frontier: every op submitted before this
    /// call has a sequence number below the returned value.
    fn frontier(&self) -> u64 {
        self.shared.state.lock().unwrap().next_seq
    }

    /// Block until every op with a sequence number below `frontier` has
    /// completed. Ops submitted concurrently with or after the
    /// `frontier` snapshot are not waited for, so this terminates even
    /// while other threads keep submitting.
    fn wait_below(&self, frontier: u64) {
        let mut st = self.shared.state.lock().unwrap();
        while st.outstanding.first().is_some_and(|&min| min < frontier) {
            st = self.shared.room.wait(st).unwrap();
        }
    }
}

impl Drop for ShardDispatcher {
    fn drop(&mut self) {
        // Workers drain whatever is queued, then exit on the flag.
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn dispatcher_worker(shared: &DispatcherShared) {
    loop {
        let queued = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(q) = st.queue.pop_front() {
                    break q;
                }
                if st.shutdown {
                    return;
                }
                st = shared.available.wait(st).unwrap();
            }
        };
        shared.stats.record_queue_wait(queued.queued_at.elapsed());
        (queued.job)();
        {
            let mut st = shared.state.lock().unwrap();
            st.outstanding.remove(&queued.seq);
        }
        shared.stats.record_op_completed();
        shared.room.notify_all();
    }
}

/// The client's asynchronous machinery: one dispatcher per shard plus
/// the parking lot for fire-and-forget push errors. Shared by all
/// clones of a [`PsClient`]; dropped (joining the worker threads) with
/// the last clone.
struct AsyncCore {
    dispatchers: Vec<ShardDispatcher>,
    /// Errors from tickets dropped before `wait` (fire-and-forget
    /// pushes); drained by [`PsClient::flush`].
    orphan_errors: Arc<Mutex<Vec<Error>>>,
}

/// Client connection to a parameter-server group. Cheap to clone; clones
/// share matrix-id allocation and the per-shard dispatch windows.
#[derive(Clone)]
pub struct PsClient {
    routes: Vec<Arc<ShardRoute>>,
    config: PsConfig,
    next_matrix_id: Arc<AtomicU32>,
    core: Arc<AsyncCore>,
}

impl PsClient {
    /// Connect through any transport — the simulated in-process network
    /// (from [`crate::ps::server::ServerGroup`]) or a TCP transport
    /// reaching shard servers in other processes.
    pub fn connect(transport: &dyn Transport, config: PsConfig) -> PsClient {
        assert_eq!(
            transport.shards(),
            config.shards,
            "transport endpoint count must match config.shards"
        );
        // Seed matrix ids from wall-clock entropy rather than 1: shard
        // servers keep matrices across client lifetimes (CreateMatrix is
        // idempotent by id + shape), so a fresh client reconnecting to
        // long-running `serve` processes must not silently adopt a
        // previous run's count tables under a recycled id. This is a
        // probabilistic guard (~n_matrices/2^32 per client pair), not a
        // coordination protocol; true multi-tenant isolation would need
        // server-assigned ids agreed across shards.
        let base = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos() ^ (d.as_secs() as u32))
            .unwrap_or(0)
            ^ std::process::id().rotate_left(16);
        let endpoints = transport.endpoints();
        // Backup endpoints when configured: `k * shards` addresses
        // describe a chain of depth `k` (tier-major), so shard `s`'s
        // failover route becomes [primary, tier1, ..., tierk].
        let backup_eps: Option<Vec<Endpoint>> = if config.backups.is_empty() {
            None
        } else {
            match crate::net::tcp::resolve_addrs(&config.backups) {
                Ok(addrs) if !addrs.is_empty() && addrs.len() % endpoints.len() == 0 => {
                    Some(crate::net::tcp::TcpTransport::connect(&addrs).endpoints())
                }
                Ok(addrs) => {
                    crate::log_warn!(
                        "ignoring backups: {} address(es) is not a whole number of \
                         {}-shard tiers",
                        addrs.len(),
                        endpoints.len()
                    );
                    None
                }
                Err(e) => {
                    crate::log_warn!("ignoring unresolvable backup addresses: {e}");
                    None
                }
            }
        };
        // Resolve the jitter seed once: 0 requests per-process entropy
        // (reusing the matrix-id base), anything else is deterministic.
        let jitter_seed = match config.retry_jitter_seed {
            0 => u64::from(base) | 1,
            s => s,
        };
        let shard_count = endpoints.len();
        let routes: Vec<Arc<ShardRoute>> = endpoints
            .into_iter()
            .enumerate()
            .map(|(s, ep)| {
                let mut eps = vec![ep];
                if let Some(backups) = &backup_eps {
                    for tier in 0..backups.len() / shard_count {
                        eps.push(backups[tier * shard_count + s].clone());
                    }
                }
                Arc::new(ShardRoute::new(
                    eps,
                    config.failover_after,
                    jitter_seed ^ ((s as u64) << 32),
                ))
            })
            .collect();
        let depth = config.pipeline_depth.max(1);
        let dispatchers = routes
            .iter()
            .enumerate()
            .map(|(s, route)| ShardDispatcher::start(s, depth, Arc::clone(&route.eps[0].stats)))
            .collect();
        PsClient {
            routes,
            config,
            next_matrix_id: Arc::new(AtomicU32::new(base.max(1))),
            core: Arc::new(AsyncCore {
                dispatchers,
                orphan_errors: Arc::new(Mutex::new(Vec::new())),
            }),
        }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.routes.len()
    }

    /// Deployment config.
    pub fn config(&self) -> &PsConfig {
        &self.config
    }

    /// A delivery agent for `shard` that async jobs can own outright.
    fn courier(&self, shard: usize) -> Courier {
        Courier {
            route: Arc::clone(&self.routes[shard]),
            shard,
            config: self.config.clone(),
        }
    }

    /// Queue `job` into `shard`'s bounded window (blocks when full).
    fn submit(&self, shard: usize, job: Job) {
        self.core.dispatchers[shard].submit(job);
    }

    /// Send `req` to `shard`, retrying with exponential back-off.
    ///
    /// Synchronous control-plane path (create, info, shutdown); data
    /// operations go through the ticket API instead. Only safe for
    /// idempotent requests (everything except a raw push without uid —
    /// which this API cannot express).
    pub fn request_retry(&self, shard: usize, req: &Request) -> Result<Response> {
        self.courier(shard).request_retry(req)
    }

    /// Barrier: block until every asynchronous operation submitted
    /// before this call has completed on every shard, then surface the
    /// first error of any fire-and-forget push whose ticket was dropped.
    ///
    /// Required before reading your own writes (perplexity evaluation,
    /// checkpointing): tickets are unordered with respect to each other
    /// until flushed. Operations submitted by other threads *while* the
    /// flush runs are not waited for, so a flushing evaluator cannot be
    /// starved by a busy producer.
    pub fn flush(&self) -> Result<()> {
        // Snapshot every shard's submission frontier first, then wait:
        // anything submitted before this call is below some frontier.
        let frontiers: Vec<u64> =
            self.core.dispatchers.iter().map(|d| d.frontier()).collect();
        for (d, &frontier) in self.core.dispatchers.iter().zip(&frontiers) {
            d.wait_below(frontier);
        }
        let mut orphans = self.core.orphan_errors.lock().unwrap();
        if orphans.is_empty() {
            return Ok(());
        }
        let first = orphans.remove(0);
        if !orphans.is_empty() {
            crate::log_warn!(
                "flush: {} further async push error(s) superseded by the first",
                orphans.len()
            );
            orphans.clear();
        }
        Err(first)
    }

    /// Allocate a distributed `rows x cols` matrix with dense shard
    /// storage (see [`PsClient::matrix_with_layout`] for sparse).
    pub fn matrix<T: Element>(&self, rows: u64, cols: u32) -> Result<BigMatrix<T>> {
        self.matrix_with_layout(rows, cols, Layout::Dense)
    }

    /// Allocate a distributed `rows x cols` matrix whose shard slices
    /// use the given storage [`Layout`]. `Layout::Sparse` stores each
    /// row as sorted `(col, val)` pairs (promoted to dense slabs above
    /// a fill threshold) — the right choice for Zipf-shaped matrices
    /// like LDA's word-topic counts, where it makes resident bytes and
    /// sparse-pull payloads proportional to occupancy.
    pub fn matrix_with_layout<T: Element>(
        &self,
        rows: u64,
        cols: u32,
        layout: Layout,
    ) -> Result<BigMatrix<T>> {
        let id = self.next_matrix_id.fetch_add(1, Ordering::SeqCst);
        self.attach_matrix(id, rows, cols, layout)
    }

    /// Attach to (or create) the matrix with an explicit, externally
    /// agreed `id` — the multi-client path: a cluster coordinator
    /// creates the epoch's count table and broadcasts the id to its
    /// workers, whose `CreateMatrix` under the same id and shape is an
    /// idempotent no-op on every shard. A shape/layout mismatch against
    /// an existing matrix of that id is rejected server-side.
    pub fn attach_matrix<T: Element>(
        &self,
        id: u32,
        rows: u64,
        cols: u32,
        layout: Layout,
    ) -> Result<BigMatrix<T>> {
        if rows == 0 || cols == 0 {
            return Err(Error::Config("matrix dimensions must be positive".into()));
        }
        let req = Request::CreateMatrix { id, rows, cols, dtype: T::DTYPE, layout };
        // Broadcast creation to every shard, in parallel.
        let results: Vec<Result<Response>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..self.shards())
                .map(|s| {
                    let req = &req;
                    scope.spawn(move || self.request_retry(s, req))
                })
                .collect();
            // PANIC-OK: join only errs when the worker itself panicked;
            // re-raising that panic is the correct propagation.
            handles.into_iter().map(|h| h.join().expect("create worker")).collect()
        });
        for r in results {
            r?;
        }
        Ok(BigMatrix {
            client: self.clone(),
            id,
            part: Partitioner::new(rows, self.config.shards, self.config.scheme),
            cols,
            layout,
            _t: PhantomData,
        })
    }

    /// Allocate a distributed vector of `len` entries (a 1-column matrix).
    pub fn vector<T: Element>(&self, len: u64) -> Result<BigVector<T>> {
        Ok(BigVector { inner: self.matrix(len, 1)? })
    }

    /// Ask every shard server to exit its serve loop. Intended for
    /// externally started `serve` processes once training is done; with
    /// an in-process [`crate::ps::server::ServerGroup`] prefer dropping
    /// the group, which shuts down over the control plane.
    ///
    /// Best-effort: every shard is attempted even when an earlier one
    /// fails (e.g. its ack was lost after it already exited); the first
    /// error is returned afterwards.
    pub fn shutdown_servers(&self) -> Result<()> {
        let mut first_err = None;
        for s in 0..self.shards() {
            let result = match self.request_retry(s, &Request::Shutdown) {
                Ok(Response::Ok) => Ok(()),
                Ok(r) => Err(Error::Decode(format!("unexpected shutdown response {r:?}"))),
                Err(e) => Err(e),
            };
            if let Err(e) = result {
                crate::log_warn!("shutdown of shard {s} failed: {e}");
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Query one shard's info (deployment layout, matrix count,
    /// resident bytes, pending uids, durability/replication state).
    /// Goes through the shard's route, so after a failover this reports
    /// on whichever replica currently serves the shard.
    pub fn shard_info(&self, shard: usize) -> Result<ShardInfo> {
        match self.request_retry(shard, &Request::ShardInfo)? {
            Response::Info {
                shard_id,
                shards,
                scheme,
                matrices,
                local_rows,
                bytes,
                pending_uids,
                dedup_evictions,
                role,
                wal_records,
                wal_bytes,
                wal_commit_batches,
                repl_applied,
                repl_lag,
            } => Ok(ShardInfo {
                shard_id,
                shards,
                scheme,
                matrices,
                local_rows,
                bytes,
                pending_uids,
                dedup_evictions,
                role,
                wal_records,
                wal_bytes,
                wal_commit_batches,
                repl_applied,
                repl_lag,
            }),
            r => Err(Error::Decode(format!("unexpected info response {r:?}"))),
        }
    }

    /// Query every shard's info.
    pub fn shard_infos(&self) -> Result<Vec<ShardInfo>> {
        (0..self.shards()).map(|s| self.shard_info(s)).collect()
    }

    /// Drop the matrix with `id` on every shard, releasing its resident
    /// bytes (and, with a WAL, letting the next compaction reclaim its
    /// log bytes). Idempotent — deleting an unknown id is a no-op — so
    /// the coordinator can retire a fenced-off epoch table best-effort.
    pub fn delete_matrix(&self, id: u32) -> Result<()> {
        let mut first_err = None;
        for s in 0..self.shards() {
            let result = match self.request_retry(s, &Request::DeleteMatrix { matrix: id }) {
                Ok(Response::Ok) => Ok(()),
                Ok(r) => Err(Error::Decode(format!("unexpected delete response {r:?}"))),
                Err(e) => Err(e),
            };
            if let Err(e) = result {
                crate::log_warn!("delete of matrix {id} on shard {s} failed: {e}");
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// A courier pinned to replica `idx` of `shard`'s route alone: the
    /// shared route may still point at a dead or gated replica, and
    /// chain surgery must address a specific position regardless.
    fn pinned_courier(&self, shard: usize, idx: usize) -> Courier {
        let route = &self.routes[shard];
        Courier {
            route: Arc::new(ShardRoute::new(
                vec![route.eps[idx].clone()],
                self.config.failover_after,
                route.jitter_seed,
            )),
            shard,
            config: self.config.clone(),
        }
    }

    /// Short `ShardInfo` probe straight at replica `idx` of `shard`'s
    /// route, bypassing the shared route and the full retry ladder.
    /// Returns `(role, repl_applied)` or `None` when unreachable.
    fn probe_replica(&self, shard: usize, idx: usize) -> Option<(u8, u64)> {
        let ep = &self.routes[shard].eps[idx];
        let payload = Request::ShardInfo.encode();
        for attempt in 0..3u32 {
            let timeout = self.config.timeout_for_attempt(attempt);
            if let Ok(bytes) = ep.request(payload.clone(), timeout) {
                if let Ok(Response::Info { role, repl_applied, .. }) = Response::decode(&bytes) {
                    return Some((role, repl_applied));
                }
            }
        }
        None
    }

    /// Promote a standby on `shard`'s failover route to serve reads and
    /// writes, then pin this client's route to it; returns the route
    /// index now serving the shard. Walks the replica chain head-ward:
    /// the first live un-promoted backup (tier 1, or tier 2 if that
    /// too is gone) is promoted, and a replica that already promoted
    /// itself is adopted as-is. The failure-detection path is the
    /// route's automatic failover; this is the *recovery* path a
    /// coordinator drives once it decides the head is gone.
    pub fn promote_backup(&self, shard: usize) -> Result<usize> {
        let route = &self.routes[shard];
        if route.eps.len() < 2 {
            return Err(Error::Config(format!("shard {shard} has no backup replica configured")));
        }
        for idx in 1..route.eps.len() {
            let Some((role, _)) = self.probe_replica(shard, idx) else {
                continue; // dead — walk further down the chain
            };
            if role == crate::ps::server::ROLE_PROMOTED {
                route.force(idx);
                return Ok(idx);
            }
            if role != crate::ps::server::ROLE_BACKUP {
                continue;
            }
            let pinned = self.pinned_courier(shard, idx);
            return match pinned.request_retry(&Request::Promote)? {
                Response::Ok => {
                    route.force(idx);
                    Ok(idx)
                }
                r => Err(Error::Decode(format!("unexpected promote response {r:?}"))),
            };
        }
        Err(Error::Config(format!("shard {shard}: no live backup replica to promote")))
    }

    /// Rebuild the standby at route position `replica` from whichever
    /// replica currently serves `shard`, and re-point its poller at
    /// `upstream` (the serving head's listen address) — how a chain
    /// heals after a promotion consumed its tier-1: the promoted head
    /// keeps serving while the stale standby is re-seeded behind it.
    /// The seed ships the head's newest snapshot slice; the standby
    /// tails the remaining log through its normal poll loop and its
    /// `repl_lag` converges without any training pause.
    pub fn reseed_backup(&self, shard: usize, replica: usize, upstream: &str) -> Result<()> {
        let route = &self.routes[shard];
        if replica == 0 || replica >= route.eps.len() {
            return Err(Error::Config(format!(
                "shard {shard} has no replica {replica} to re-seed"
            )));
        }
        // The head's snapshot slice (a compacted head answers with its
        // snapshot; an uncompacted one streams from sequence 1 — either
        // way the seed rebuilds the standby from nothing).
        let (tip, records) = match self.request_retry(shard, &Request::ReplPoll { from: 1 })? {
            Response::ReplBatch { tip, records, .. } => (tip, records),
            r => return Err(Error::Decode(format!("unexpected repl-poll response {r:?}"))),
        };
        let pinned = self.pinned_courier(shard, replica);
        let seed = Request::ReplSeed { upstream: upstream.to_string(), tip, records };
        match pinned.request_retry(&seed)? {
            Response::Ok => Ok(()),
            r => Err(Error::Decode(format!("unexpected repl-seed response {r:?}"))),
        }
    }

    /// Planned hand-off of `shard` to a standby with zero data loss:
    /// drain the serving head (it freezes writes, fsyncs, and reports
    /// its committed tip), wait for a standby to replicate through that
    /// tip, promote it, and pin the route; returns the new serving
    /// route index. Because the tip covers the entire commit window,
    /// nothing is lost and the caller needs no epoch roll — in-flight
    /// couriers just retry their `Unavailable` answers onto the new
    /// head.
    pub fn drain_shard(&self, shard: usize) -> Result<usize> {
        let route = &self.routes[shard];
        if route.eps.len() < 2 {
            return Err(Error::Config(format!(
                "shard {shard} has no standby to drain onto"
            )));
        }
        let tip = match self.request_retry(shard, &Request::Drain)? {
            Response::Drained { tip } => tip,
            r => return Err(Error::Decode(format!("unexpected drain response {r:?}"))),
        };
        let drained = route.active();
        let deadline = Instant::now() + self.config.max_timeout;
        loop {
            // The most caught-up live standby (any position except the
            // drained head; dead or non-backup replicas are skipped).
            let mut best: Option<(usize, u64)> = None;
            for idx in (0..route.eps.len()).filter(|&i| i != drained) {
                if let Some((role, applied)) = self.probe_replica(shard, idx) {
                    if role == crate::ps::server::ROLE_BACKUP
                        && best.map_or(true, |(_, a)| applied > a)
                    {
                        best = Some((idx, applied));
                    }
                }
            }
            match best {
                Some((idx, applied)) if applied >= tip => {
                    let pinned = self.pinned_courier(shard, idx);
                    return match pinned.request_retry(&Request::Promote)? {
                        Response::Ok => {
                            route.force(idx);
                            Ok(idx)
                        }
                        r => Err(Error::Decode(format!("unexpected promote response {r:?}"))),
                    };
                }
                _ if Instant::now() >= deadline => {
                    return Err(Error::Config(format!(
                        "shard {shard}: no standby reached the drain tip {tip} within {:?}",
                        self.config.max_timeout
                    )));
                }
                // The tip is at most one commit window away; re-probe
                // on a short cadence rather than the retry ladder.
                _ => std::thread::sleep(Duration::from_millis(10)),
            }
        }
    }

    /// Role of every replica on `shard`'s route, by route position
    /// (`None` = unreachable) — chain-health introspection for
    /// coordinators deciding which standbys need a re-seed.
    pub fn replica_roles(&self, shard: usize) -> Vec<Option<u8>> {
        (0..self.routes[shard].eps.len())
            .map(|idx| self.probe_replica(shard, idx).map(|(role, _)| role))
            .collect()
    }

    /// Retries provoked by `Unavailable` answers on `shard`'s route
    /// since connect — the counter drain demos assert stays bounded.
    pub fn unavailable_retries(&self, shard: usize) -> u64 {
        self.routes[shard].unavailable_retry_count()
    }

    /// Verify this client's deployment view against what every shard
    /// server reports: address order must match shard ids, and shard
    /// count and partitioning scheme must agree — otherwise pushes and
    /// pulls would silently land on the wrong rows. Essential before
    /// training over `--connect`.
    pub fn validate_deployment(&self) -> Result<()> {
        for (expect, info) in self.shard_infos()?.into_iter().enumerate() {
            if info.shard_id as usize != expect {
                return Err(Error::Config(format!(
                    "endpoint {expect} is shard {} — the connect address list is out of order",
                    info.shard_id
                )));
            }
            if info.shards as usize != self.config.shards {
                return Err(Error::Config(format!(
                    "server reports a {}-shard deployment but this client connects {} \
                     endpoint(s); row partitioning would disagree",
                    info.shards,
                    self.config.shards
                )));
            }
            if info.scheme != self.config.scheme {
                return Err(Error::Config(format!(
                    "server partitions rows with the {:?} scheme, client is configured \
                     for {:?}",
                    info.scheme, self.config.scheme
                )));
            }
        }
        Ok(())
    }
}

/// One shard server's introspection report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardInfo {
    /// The server's global shard id.
    pub shard_id: u32,
    /// Total shards in the server's deployment.
    pub shards: u32,
    /// Row partitioning scheme on the server.
    pub scheme: crate::ps::partition::PartitionScheme,
    /// Matrices hosted.
    pub matrices: u32,
    /// Total local rows across matrices.
    pub local_rows: u64,
    /// Payload bytes resident.
    pub bytes: u64,
    /// Outstanding (un-forgotten) push uids.
    pub pending_uids: u64,
    /// Dedup records evicted by the server's bounded window before
    /// their `Forget` arrived (abandoned hand-shakes).
    pub dedup_evictions: u64,
    /// Replication role: 0 = primary, 1 = un-promoted backup,
    /// 2 = promoted backup, 3 = draining head (see
    /// `crate::ps::server::ROLE_PRIMARY` etc.).
    pub role: u8,
    /// Records appended to the shard's write-ahead log (0 without one).
    pub wal_records: u64,
    /// Bytes across the WAL's segments.
    pub wal_bytes: u64,
    /// Group-commit fsync batches the WAL has written.
    pub wal_commit_batches: u64,
    /// Highest replicated log sequence this shard has applied (backups).
    pub repl_applied: u64,
    /// Known committed primary records not yet applied here (backups).
    pub repl_lag: u64,
}

/// Sparse additive deltas destined for one matrix, grouped per shard by
/// the client before pushing.
#[derive(Debug, Clone, Default)]
pub struct CoordDeltas<T> {
    /// Global rows.
    pub rows: Vec<u64>,
    /// Columns.
    pub cols: Vec<u32>,
    /// Delta values.
    pub values: Vec<T>,
}

impl<T> CoordDeltas<T> {
    /// Number of deltas.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no deltas.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// One pulled sparse row: `(col, value)` pairs, columns ascending for
/// plain sparse pulls, value-descending for top-k pulls.
pub type SparseRow<T> = Vec<(u32, T)>;

/// Per-shard reply of a sparse pull: `(lens, cols, values)` in the
/// shard's request order.
type SparseShardReply<T> = (Vec<u32>, Vec<u32>, Vec<T>);

/// Receive one shard's reply off an async worker channel; a hung-up
/// channel means the dispatcher died before replying.
fn recv_part<V>(rx: &mpsc::Receiver<Result<V>>, what: &str) -> Result<V> {
    match rx.recv() {
        Ok(r) => r,
        Err(_) => Err(Error::Config(format!("async {what} worker disappeared before replying"))),
    }
}

/// How a [`Ticket`] resolves at `wait` time.
enum TicketState<R> {
    /// Resolved at issue time: trivial operations (nothing to send) and
    /// validation failures of side-effect-free operations.
    Ready(Option<Result<R>>),
    /// Pull-style: a deferred gather that receives every shard's reply
    /// and scatters them back into request order. Dropping it abandons
    /// the values (the pulls still complete on the shard workers).
    Gather(Option<Box<dyn FnOnce() -> Result<R> + Send>>),
    /// Push-style: per-shard exactly-once hand-shake completion slots.
    /// Dropping it fires-and-forgets — errors are parked in the orphan
    /// sink for the next flush.
    Push { parts: Vec<Arc<PushPart>>, early: Option<Error>, ok: Option<R> },
}

/// Handle to an asynchronous parameter-server operation. One type for
/// every operation, generic over the result it delivers:
///
/// - `Ticket<Vec<T>>` — dense row pulls ([`BigMatrix::pull_rows_async`])
///   and column sums ([`BigMatrix::pull_col_sums_async`]);
/// - `Ticket<Vec<SparseRow<T>>>` — sparse and top-k pulls;
/// - `Ticket<()>` — exactly-once pushes.
///
/// [`Ticket::wait`] is the one resolution contract: block until every
/// per-shard sub-operation finished, first error wins. Dropping a pull
/// ticket abandons its values (the pull still completes inside the
/// shard windows); dropping a push ticket makes the push
/// fire-and-forget — it still runs to completion and any error is
/// parked for the next [`PsClient::flush`].
#[must_use = "an operation's outcome is only delivered through wait()"]
pub struct Ticket<R> {
    state: TicketState<R>,
    /// The client's orphan-error sink (push-style tickets only).
    orphans: Option<Arc<Mutex<Vec<Error>>>>,
}

impl<R> Ticket<R> {
    /// A ticket resolved at issue time (trivial or invalid operation).
    fn ready(result: Result<R>) -> Ticket<R> {
        Ticket { state: TicketState::Ready(Some(result)), orphans: None }
    }

    /// A ticket that resolves by running `gather` (receive per-shard
    /// replies + scatter) when waited.
    fn gather(f: impl FnOnce() -> Result<R> + Send + 'static) -> Ticket<R> {
        Ticket { state: TicketState::Gather(Some(Box::new(f))), orphans: None }
    }

    /// Block until the operation completed on every shard; first error
    /// wins. Pulls yield their values; pushes yield `()` once every
    /// shard's hand-shake confirmed exactly-once application.
    pub fn wait(mut self) -> Result<R> {
        match std::mem::replace(&mut self.state, TicketState::Ready(None)) {
            // PANIC-OK: `wait` consumes the ticket, so a twice-waited
            // ticket is unreachable; the expects document the invariant.
            TicketState::Ready(result) => result.expect("ticket waited twice"),
            TicketState::Gather(f) => (f.expect("ticket waited twice"))(),
            TicketState::Push { parts, early, ok } => {
                if let Some(e) = early {
                    // Constructors never pair an early error with
                    // submitted parts, but keep the never-silent
                    // invariant anyway: park whatever exists.
                    park_push_parts(&parts, self.orphans.as_deref());
                    return Err(e);
                }
                let mut first: Option<Error> = None;
                for part in &parts {
                    let mut st = part.state.lock().unwrap();
                    while st.result.is_none() {
                        st = part.done.wait(st).unwrap();
                    }
                    if let Some(Err(e)) = st.result.take() {
                        first.get_or_insert(e);
                    }
                }
                match first {
                    Some(e) => Err(e),
                    // PANIC-OK: same consumed-ticket invariant as above.
                    None => Ok(ok.expect("ticket waited twice")),
                }
            }
        }
    }
}

impl<R> Drop for Ticket<R> {
    fn drop(&mut self) {
        // Pull-style states need no cleanup: dropping the gather closure
        // drops its receivers, and the shard jobs discard their sends.
        // A dropped push must never fail silently: hand any un-consumed
        // results to the orphan sink (results a `wait` already took are
        // gone; jobs still running see the abandoned flag and park their
        // own errors). A validation failure nobody waited for is parked
        // the same way.
        let TicketState::Push { parts, early, .. } =
            std::mem::replace(&mut self.state, TicketState::Ready(None))
        else {
            return;
        };
        if let Some(e) = early {
            if let Some(orphans) = self.orphans.as_deref() {
                orphans.lock().unwrap().push(e);
            }
        }
        park_push_parts(&parts, self.orphans.as_deref());
    }
}

/// Route every un-consumed push-part outcome into the orphan sink: an
/// error is parked for the next flush, a still-running hand-shake is
/// flagged abandoned so its job parks its own error when it completes.
fn park_push_parts(parts: &[Arc<PushPart>], orphans: Option<&Mutex<Vec<Error>>>) {
    let Some(orphans) = orphans else {
        return;
    };
    for part in parts {
        let mut st = part.state.lock().unwrap();
        match st.result.take() {
            Some(Err(e)) => orphans.lock().unwrap().push(e),
            Some(Ok(())) => {}
            None => st.abandoned = true,
        }
    }
}

/// Completion slot shared between one shard's push job and its ticket.
///
/// A mutex-guarded hand-off (rather than a channel) so the error of a
/// fire-and-forget push can never fall between the cracks: whichever of
/// {job completion, ticket drop} happens first, the slot's state tells
/// the other side exactly who owns error reporting.
struct PushPart {
    state: Mutex<PushPartState>,
    done: Condvar,
}

struct PushPartState {
    result: Option<Result<()>>,
    /// The ticket was dropped without `wait`: the job must route an
    /// error to the client's orphan sink instead.
    abandoned: bool,
}

impl PushPart {
    fn new() -> PushPart {
        PushPart {
            state: Mutex::new(PushPartState { result: None, abandoned: false }),
            done: Condvar::new(),
        }
    }

    /// Called by the shard job when the hand-shake finishes.
    fn complete(&self, orphans: &Mutex<Vec<Error>>, result: Result<()>) {
        let mut st = self.state.lock().unwrap();
        if st.abandoned {
            if let Err(e) = result {
                orphans.lock().unwrap().push(e);
            }
        } else {
            st.result = Some(result);
            self.done.notify_all();
        }
    }
}

/// Handle to a distributed `rows x cols` matrix of `T`.
///
/// The handle is clonable and thread-safe; concurrent pushes from many
/// workers are the intended use (the counts are commutative).
#[derive(Clone)]
pub struct BigMatrix<T: Element> {
    client: PsClient,
    id: u32,
    part: Partitioner,
    cols: u32,
    layout: Layout,
    _t: PhantomData<T>,
}

impl<T: Element> BigMatrix<T> {
    /// Global rows.
    pub fn rows(&self) -> u64 {
        self.part.rows
    }

    /// Columns.
    pub fn cols(&self) -> u32 {
        self.cols
    }

    /// Matrix id (diagnostics).
    pub fn id(&self) -> u32 {
        self.id
    }

    /// Shard storage layout.
    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// Submit one shard's exactly-once push hand-shake (built by `make`
    /// from the allocated uid) into that shard's window; the returned
    /// part completes when the hand-shake does.
    fn submit_push(
        &self,
        shard: usize,
        make: impl Fn(u64) -> Request + Send + 'static,
    ) -> Arc<PushPart> {
        let courier = self.client.courier(shard);
        let orphans = Arc::clone(&self.client.core.orphan_errors);
        let part = Arc::new(PushPart::new());
        let job_part = Arc::clone(&part);
        self.client.submit(
            shard,
            Box::new(move || {
                let result = courier.handshake_push(&make);
                job_part.complete(&orphans, result);
            }),
        );
        part
    }

    /// Assemble the ticket for a set of submitted push parts.
    fn push_ticket(&self, parts: Vec<Arc<PushPart>>) -> Ticket<()> {
        Ticket {
            state: TicketState::Push { parts, early: None, ok: Some(()) },
            orphans: Some(Arc::clone(&self.client.core.orphan_errors)),
        }
    }

    /// A push ticket that fails immediately with `err` when waited; if
    /// nobody waits, the error is parked for `flush` instead (dropped
    /// tickets must never fail silently).
    fn failed_push(&self, err: Error) -> Ticket<()> {
        Ticket {
            state: TicketState::Push { parts: Vec::new(), early: Some(err), ok: Some(()) },
            orphans: Some(Arc::clone(&self.client.core.orphan_errors)),
        }
    }

    /// Start pulling full rows by global index; the returned ticket's
    /// [`Ticket::wait`] yields the values row-major in the order
    /// requested. The per-shard sub-requests run inside each shard's
    /// bounded in-flight window, so several tickets can overlap.
    pub fn pull_rows_async(&self, rows: &[u64]) -> Ticket<Vec<T>> {
        let shards = self.client.shards();
        if rows.is_empty() {
            return Ticket::ready(Ok(Vec::new()));
        }
        for &r in rows {
            if r >= self.part.rows {
                return Ticket::ready(Err(Error::Config(format!(
                    "row {r} out of bounds ({} rows)",
                    self.part.rows
                ))));
            }
        }
        // Split into at most one request per shard (§2.3).
        let mut per_shard: Vec<Vec<u64>> = vec![Vec::new(); shards];
        for &r in rows {
            per_shard[self.part.shard_of(r)].push(r);
        }
        let mut parts = Vec::new();
        for (s, shard_rows) in per_shard.into_iter().enumerate() {
            if shard_rows.is_empty() {
                continue;
            }
            let courier = self.client.courier(s);
            let req = Request::PullRows { id: self.id, rows: shard_rows };
            let (tx, rx) = mpsc::channel();
            self.client.submit(
                s,
                Box::new(move || {
                    let result = courier.request_retry(&req).and_then(|resp| match resp {
                        Response::Rows(data) => T::unwrap(data),
                        r => Err(Error::Decode(format!("unexpected pull response {r:?}"))),
                    });
                    // The ticket may have been dropped; a pull has no
                    // side effects, so its result can be discarded.
                    let _ = tx.send(result);
                }),
            );
            parts.push((s, rx));
        }
        let rows = rows.to_vec();
        let cols = self.cols as usize;
        let part = self.part;
        Ticket::gather(move || {
            let mut shard_data: Vec<Vec<T>> = (0..shards).map(|_| Vec::new()).collect();
            for (shard, rx) in &parts {
                shard_data[*shard] = recv_part(rx, "pull")?;
            }
            // Scatter back into request order.
            let mut cursor = vec![0usize; shards];
            let mut out = vec![T::default(); rows.len() * cols];
            for (i, &r) in rows.iter().enumerate() {
                let s = part.shard_of(r);
                let src = &shard_data[s][cursor[s]..cursor[s] + cols];
                out[i * cols..(i + 1) * cols].copy_from_slice(src);
                cursor[s] += cols;
            }
            Ok(out)
        })
    }

    /// Pull full rows by global index; returns values row-major in the
    /// order requested (`rows.len() * cols` entries). Blocking wrapper
    /// over [`BigMatrix::pull_rows_async`].
    pub fn pull_rows(&self, rows: &[u64]) -> Result<Vec<T>> {
        self.pull_rows_async(rows).wait()
    }

    /// Pull a single row.
    pub fn pull_row(&self, row: u64) -> Result<Vec<T>> {
        self.pull_rows(&[row])
    }

    /// Issue one sparse pull sub-request per shard; `make` builds the
    /// shard request from that shard's row subset. Shared machinery of
    /// [`BigMatrix::pull_sparse_rows_async`] and
    /// [`BigMatrix::pull_topk_async`].
    fn sparse_pull_async(
        &self,
        rows: &[u64],
        make: impl Fn(u32, Vec<u64>) -> Request,
    ) -> Ticket<Vec<SparseRow<T>>> {
        let shards = self.client.shards();
        if rows.is_empty() {
            return Ticket::ready(Ok(Vec::new()));
        }
        for &r in rows {
            if r >= self.part.rows {
                return Ticket::ready(Err(Error::Config(format!(
                    "row {r} out of bounds ({} rows)",
                    self.part.rows
                ))));
            }
        }
        // Split into at most one request per shard (§2.3).
        let mut per_shard: Vec<Vec<u64>> = vec![Vec::new(); shards];
        for &r in rows {
            per_shard[self.part.shard_of(r)].push(r);
        }
        let mut parts = Vec::new();
        for (s, shard_rows) in per_shard.into_iter().enumerate() {
            if shard_rows.is_empty() {
                continue;
            }
            let courier = self.client.courier(s);
            let req = make(self.id, shard_rows);
            let (tx, rx) = mpsc::channel();
            self.client.submit(
                s,
                Box::new(move || {
                    let result = courier.request_retry(&req).and_then(|resp| match resp {
                        Response::SparseRows(d) => {
                            let vals = T::unwrap(d.values)?;
                            Ok((d.lens, d.cols, vals))
                        }
                        r => Err(Error::Decode(format!("unexpected sparse pull response {r:?}"))),
                    });
                    // The ticket may have been dropped; a pull has no
                    // side effects, so its result can be discarded.
                    let _ = tx.send(result);
                }),
            );
            parts.push((s, rx));
        }
        let rows = rows.to_vec();
        let part = self.part;
        Ticket::gather(move || {
            let mut shard_data: Vec<SparseShardReply<T>> =
                (0..shards).map(|_| (Vec::new(), Vec::new(), Vec::new())).collect();
            for (shard, rx) in &parts {
                shard_data[*shard] = recv_part(rx, "sparse pull")?;
            }
            // Scatter back into request order.
            let mut row_cursor = vec![0usize; shards];
            let mut pair_cursor = vec![0usize; shards];
            let mut out: Vec<SparseRow<T>> = Vec::with_capacity(rows.len());
            for &r in &rows {
                let s = part.shard_of(r);
                let (lens, cols, vals) = &shard_data[s];
                let Some(&n) = lens.get(row_cursor[s]) else {
                    return Err(Error::Decode("sparse pull reply is missing rows".into()));
                };
                row_cursor[s] += 1;
                let (start, end) = (pair_cursor[s], pair_cursor[s] + n as usize);
                if end > cols.len() || end > vals.len() {
                    return Err(Error::Decode("sparse pull reply is missing pairs".into()));
                }
                out.push(
                    cols[start..end]
                        .iter()
                        .copied()
                        .zip(vals[start..end].iter().copied())
                        .collect(),
                );
                pair_cursor[s] = end;
            }
            Ok(out)
        })
    }

    /// Start pulling rows as `(col, value)` pair lists — only the
    /// non-zero entries cross the wire, so bandwidth is proportional to
    /// row occupancy rather than `cols`. The ticket's wait() yields one
    /// column-ascending pair list per requested row, in request order —
    /// the pair lists are the end product, never densified by this
    /// layer: the sampler's pull pipeline
    /// ([`crate::lda::pipeline::BlockData::Sparse`]) hands them to the
    /// sweep as-is, so client-side block memory is O(pairs) too.
    /// Works on either storage layout (dense shards scan for non-zero
    /// entries server-side).
    pub fn pull_sparse_rows_async(&self, rows: &[u64]) -> Ticket<Vec<SparseRow<T>>> {
        self.sparse_pull_async(rows, |id, shard_rows| Request::PullSparseRows {
            id,
            rows: shard_rows,
        })
    }

    /// Pull rows as sparse pair lists. Blocking wrapper over
    /// [`BigMatrix::pull_sparse_rows_async`].
    pub fn pull_sparse_rows(&self, rows: &[u64]) -> Result<Vec<SparseRow<T>>> {
        self.pull_sparse_rows_async(rows).wait()
    }

    /// Start a server-side top-k pull: each requested row comes back as
    /// its `k` largest `(col, value)` pairs (value descending, ties by
    /// column ascending) — topic inspection without shipping full rows.
    pub fn pull_topk_async(&self, rows: &[u64], k: u32) -> Ticket<Vec<SparseRow<T>>> {
        self.sparse_pull_async(rows, move |id, shard_rows| Request::PullTopK {
            id,
            rows: shard_rows,
            k,
        })
    }

    /// Server-side top-k per row. Blocking wrapper over
    /// [`BigMatrix::pull_topk_async`].
    pub fn pull_topk(&self, rows: &[u64], k: u32) -> Result<Vec<SparseRow<T>>> {
        self.pull_topk_async(rows, k).wait()
    }

    /// Start a server-side column-sum aggregation: every shard sums its
    /// local rows and ships one `cols`-length vector; the ticket adds
    /// the partials. For LDA this replaces pulling the whole word-topic
    /// matrix just to recompute the global topic-count vector.
    pub fn pull_col_sums_async(&self) -> Ticket<Vec<T>> {
        let mut parts = Vec::with_capacity(self.client.shards());
        for s in 0..self.client.shards() {
            let courier = self.client.courier(s);
            let req = Request::PullColSums { id: self.id };
            let (tx, rx) = mpsc::channel();
            self.client.submit(
                s,
                Box::new(move || {
                    let result = courier.request_retry(&req).and_then(|resp| match resp {
                        Response::Rows(data) => T::unwrap(data),
                        r => Err(Error::Decode(format!("unexpected col-sum response {r:?}"))),
                    });
                    let _ = tx.send(result);
                }),
            );
            parts.push(rx);
        }
        let cols = self.cols as usize;
        Ticket::gather(move || {
            let mut out = vec![T::default(); cols];
            for rx in &parts {
                let partial = recv_part(rx, "col-sum")?;
                if partial.len() != cols {
                    return Err(Error::Decode(format!(
                        "col-sum reply has {} entries, want {cols}",
                        partial.len()
                    )));
                }
                for (o, v) in out.iter_mut().zip(partial) {
                    *o += v;
                }
            }
            Ok(out)
        })
    }

    /// Global column sums. Blocking wrapper over
    /// [`BigMatrix::pull_col_sums_async`].
    pub fn pull_col_sums(&self) -> Result<Vec<T>> {
        self.pull_col_sums_async().wait()
    }

    /// Start pushing sparse additive deltas with exactly-once semantics.
    ///
    /// Deltas are grouped per shard; each shard group runs the hand-shake
    /// independently inside that shard's in-flight window. Dropping the
    /// ticket fires-and-forgets; errors then surface at the next
    /// [`BigMatrix::flush`].
    pub fn push_coords_async(&self, deltas: &CoordDeltas<T>) -> Ticket<()> {
        if deltas.is_empty() {
            return Ticket::ready(Ok(()));
        }
        if deltas.rows.len() != deltas.cols.len() || deltas.rows.len() != deltas.values.len() {
            return self.failed_push(Error::Config("delta arrays must have equal length".into()));
        }
        let shards = self.client.shards();
        let mut per_shard: Vec<CoordDeltas<T>> =
            (0..shards).map(|_| CoordDeltas::default()).collect();
        for ((&r, &c), &v) in deltas.rows.iter().zip(&deltas.cols).zip(&deltas.values) {
            if r >= self.part.rows || c >= self.cols {
                return self.failed_push(Error::Config(format!(
                    "delta ({r},{c}) out of bounds for {}x{}",
                    self.part.rows, self.cols
                )));
            }
            let s = self.part.shard_of(r);
            per_shard[s].rows.push(r);
            per_shard[s].cols.push(c);
            per_shard[s].values.push(v);
        }
        let id = self.id;
        let mut parts = Vec::new();
        for (s, group) in per_shard.into_iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            parts.push(self.submit_push(s, move |uid| Request::PushCoords {
                id,
                uid,
                rows: group.rows.clone(),
                cols: group.cols.clone(),
                values: T::wrap(group.values.clone()),
            }));
        }
        self.push_ticket(parts)
    }

    /// Push sparse additive deltas with exactly-once semantics. Blocking
    /// wrapper over [`BigMatrix::push_coords_async`].
    pub fn push_coords(&self, deltas: &CoordDeltas<T>) -> Result<()> {
        self.push_coords_async(deltas).wait()
    }

    /// Start pushing dense full-row deltas (`rows.len() * cols` values,
    /// row-major) with exactly-once semantics. Same ticket semantics as
    /// [`BigMatrix::push_coords_async`].
    pub fn push_rows_async(&self, rows: &[u64], values: &[T]) -> Ticket<()> {
        if rows.is_empty() {
            return Ticket::ready(Ok(()));
        }
        let cols = self.cols as usize;
        if values.len() != rows.len() * cols {
            return self.failed_push(Error::Config(format!(
                "push_rows shape mismatch: {} values for {} rows x {} cols",
                values.len(),
                rows.len(),
                cols
            )));
        }
        let shards = self.client.shards();
        let mut shard_rows: Vec<Vec<u64>> = vec![Vec::new(); shards];
        let mut shard_vals: Vec<Vec<T>> = vec![Vec::new(); shards];
        for (i, &r) in rows.iter().enumerate() {
            if r >= self.part.rows {
                return self.failed_push(Error::Config(format!("row {r} out of bounds")));
            }
            let s = self.part.shard_of(r);
            shard_rows[s].push(r);
            shard_vals[s].extend_from_slice(&values[i * cols..(i + 1) * cols]);
        }
        let id = self.id;
        let mut parts = Vec::new();
        for (s, (rws, vls)) in shard_rows.into_iter().zip(shard_vals).enumerate() {
            if rws.is_empty() {
                continue;
            }
            parts.push(self.submit_push(s, move |uid| Request::PushRows {
                id,
                uid,
                rows: rws.clone(),
                values: T::wrap(vls.clone()),
            }));
        }
        self.push_ticket(parts)
    }

    /// Push dense full-row deltas with exactly-once semantics. Blocking
    /// wrapper over [`BigMatrix::push_rows_async`].
    pub fn push_rows(&self, rows: &[u64], values: &[T]) -> Result<()> {
        self.push_rows_async(rows, values).wait()
    }

    /// Barrier over the whole client — see [`PsClient::flush`].
    pub fn flush(&self) -> Result<()> {
        self.client.flush()
    }
}

/// Handle to a distributed vector (1-column matrix).
#[derive(Clone)]
pub struct BigVector<T: Element> {
    inner: BigMatrix<T>,
}

impl<T: Element> BigVector<T> {
    /// Length.
    pub fn len(&self) -> u64 {
        self.inner.rows()
    }

    /// Always false (vectors are non-empty by construction).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Start pulling selected entries (ticket semantics of
    /// [`BigMatrix::pull_rows_async`]).
    pub fn pull_async(&self, indices: &[u64]) -> Ticket<Vec<T>> {
        self.inner.pull_rows_async(indices)
    }

    /// Pull selected entries.
    pub fn pull(&self, indices: &[u64]) -> Result<Vec<T>> {
        self.inner.pull_rows(indices)
    }

    /// Pull the entire vector.
    pub fn pull_all(&self) -> Result<Vec<T>> {
        let indices: Vec<u64> = (0..self.len()).collect();
        self.pull(&indices)
    }

    /// Start pushing sparse additive deltas (ticket semantics of
    /// [`BigMatrix::push_coords_async`]).
    pub fn push_async(&self, indices: &[u64], deltas: &[T]) -> Ticket<()> {
        if indices.len() != deltas.len() {
            return self.inner.failed_push(Error::Config(
                "index and delta arrays must have equal length".into(),
            ));
        }
        let cd = CoordDeltas {
            rows: indices.to_vec(),
            cols: vec![0; indices.len()],
            values: deltas.to_vec(),
        };
        self.inner.push_coords_async(&cd)
    }

    /// Push sparse additive deltas.
    pub fn push(&self, indices: &[u64], deltas: &[T]) -> Result<()> {
        self.push_async(indices, deltas).wait()
    }

    /// Barrier over the whole client — see [`PsClient::flush`].
    pub fn flush(&self) -> Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::FaultPlan;
    use crate::ps::server::ServerGroup;

    fn setup(shards: usize, plan: FaultPlan) -> (ServerGroup, PsClient) {
        let cfg = PsConfig::with_shards(shards);
        let group = ServerGroup::start(cfg.clone(), plan, 42);
        let client = PsClient::connect(&group.transport(), cfg);
        (group, client)
    }

    #[test]
    fn matrix_pull_initially_zero() {
        let (_g, client) = setup(3, FaultPlan::reliable());
        let m: BigMatrix<i64> = client.matrix(10, 4).unwrap();
        let vals = m.pull_rows(&[0, 3, 9]).unwrap();
        assert_eq!(vals, vec![0; 12]);
    }

    #[test]
    fn push_then_pull_roundtrip() {
        let (_g, client) = setup(4, FaultPlan::reliable());
        let m: BigMatrix<i64> = client.matrix(100, 5).unwrap();
        let deltas = CoordDeltas {
            rows: vec![0, 1, 50, 99, 0],
            cols: vec![0, 1, 2, 4, 0],
            values: vec![3, -1, 7, 2, 4],
        };
        m.push_coords(&deltas).unwrap();
        let vals = m.pull_rows(&[0, 1, 50, 99]).unwrap();
        assert_eq!(vals[0], 7); // 3 + 4 accumulated
        assert_eq!(vals[5 + 1], -1);
        assert_eq!(vals[10 + 2], 7);
        assert_eq!(vals[15 + 4], 2);
    }

    #[test]
    fn push_rows_dense() {
        let (_g, client) = setup(2, FaultPlan::reliable());
        let m: BigMatrix<f32> = client.matrix(4, 3).unwrap();
        m.push_rows(&[1, 2], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        m.push_rows(&[1], &[0.5, 0.5, 0.5]).unwrap();
        let vals = m.pull_rows(&[1, 2]).unwrap();
        assert_eq!(vals, vec![1.5, 2.5, 3.5, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn vector_ops() {
        let (_g, client) = setup(3, FaultPlan::reliable());
        let v: BigVector<i64> = client.vector(7).unwrap();
        v.push(&[0, 6, 0], &[5, 10, 1]).unwrap();
        assert_eq!(v.pull_all().unwrap(), vec![6, 0, 0, 0, 0, 0, 10]);
    }

    #[test]
    fn out_of_bounds_rejected_client_side() {
        let (_g, client) = setup(2, FaultPlan::reliable());
        let m: BigMatrix<i64> = client.matrix(5, 2).unwrap();
        assert!(m.pull_rows(&[5]).is_err());
        let bad = CoordDeltas { rows: vec![0], cols: vec![9], values: vec![1] };
        assert!(m.push_coords(&bad).is_err());
    }

    #[test]
    fn exactly_once_under_lossy_network() {
        // 20% request loss, 20% reply loss, 10% duplication: the sum of
        // all deltas must still be applied exactly once each.
        let (_g, client) = setup(3, FaultPlan::lossy(0.2, 0.1));
        let m: BigMatrix<i64> = client.matrix(30, 2).unwrap();
        let mut expect = vec![0i64; 30 * 2];
        for round in 0..20 {
            let deltas = CoordDeltas {
                rows: vec![round % 30, (round * 7) % 30],
                cols: vec![0, 1],
                values: vec![1, 2],
            };
            expect[(deltas.rows[0] * 2) as usize] += 1;
            expect[(deltas.rows[1] * 2 + 1) as usize] += 2;
            m.push_coords(&deltas).unwrap();
        }
        let all: Vec<u64> = (0..30).collect();
        let got = m.pull_rows(&all).unwrap();
        assert_eq!(got, expect);
    }

    #[test]
    fn concurrent_pushers_accumulate() {
        let (_g, client) = setup(4, FaultPlan::reliable());
        let m: BigMatrix<i64> = client.matrix(16, 1).unwrap();
        std::thread::scope(|scope| {
            for t in 0..8 {
                let m = m.clone();
                scope.spawn(move || {
                    for i in 0..50 {
                        let deltas = CoordDeltas {
                            rows: vec![((t * 50 + i) % 16) as u64],
                            cols: vec![0],
                            values: vec![1],
                        };
                        m.push_coords(&deltas).unwrap();
                    }
                });
            }
        });
        let all: Vec<u64> = (0..16).collect();
        let got = m.pull_rows(&all).unwrap();
        assert_eq!(got.iter().sum::<i64>(), 8 * 50);
    }

    #[test]
    fn total_loss_times_out_with_error() {
        let cfg = PsConfig {
            shards: 1,
            max_retries: 3,
            timeout: std::time::Duration::from_millis(5),
            ..PsConfig::default()
        };
        let group = ServerGroup::start(
            cfg.clone(),
            FaultPlan { drop_request: 1.0, ..FaultPlan::default() },
            7,
        );
        let client = PsClient::connect(&group.transport(), cfg);
        match client.matrix::<i64>(4, 1) {
            Err(Error::PsTimeout { attempts, .. }) => assert_eq!(attempts, 3),
            Err(e) => panic!("unexpected error {e}"),
            Ok(_) => panic!("matrix creation should have timed out"),
        }
    }

    #[test]
    fn overlapping_tickets_resolve_independently() {
        let cfg = PsConfig { pipeline_depth: 4, ..PsConfig::with_shards(2) };
        let group = ServerGroup::start(cfg.clone(), FaultPlan::reliable(), 17);
        let client = PsClient::connect(&group.transport(), cfg);
        let m: BigMatrix<i64> = client.matrix(32, 2).unwrap();
        // Issue several pushes and pulls without waiting in between.
        let pushes: Vec<Ticket<()>> = (0..6)
            .map(|i| {
                let deltas = CoordDeltas { rows: vec![i], cols: vec![0], values: vec![1] };
                m.push_coords_async(&deltas)
            })
            .collect();
        for t in pushes {
            t.wait().unwrap();
        }
        let t_a = m.pull_rows_async(&[0, 1, 2]);
        let t_b = m.pull_rows_async(&[3, 4, 5]);
        let b = t_b.wait().unwrap();
        let a = t_a.wait().unwrap();
        assert_eq!(a, vec![1, 0, 1, 0, 1, 0]);
        assert_eq!(b, vec![1, 0, 1, 0, 1, 0]);
    }

    #[test]
    fn fire_and_forget_then_flush_is_a_barrier() {
        let (_g, client) = setup(3, FaultPlan::reliable());
        let m: BigMatrix<i64> = client.matrix(24, 1).unwrap();
        for i in 0..48u64 {
            // Tickets dropped immediately: fire-and-forget.
            let _ = m.push_coords_async(&CoordDeltas {
                rows: vec![i % 24],
                cols: vec![0],
                values: vec![1],
            });
        }
        client.flush().unwrap();
        let all: Vec<u64> = (0..24).collect();
        let got = m.pull_rows(&all).unwrap();
        assert_eq!(got.iter().sum::<i64>(), 48);
    }

    #[test]
    fn sparse_pull_matches_dense_on_both_layouts() {
        let (_g, client) = setup(3, FaultPlan::reliable());
        for layout in [Layout::Dense, Layout::Sparse] {
            let m: BigMatrix<i64> = client.matrix_with_layout(40, 6, layout).unwrap();
            assert_eq!(m.layout(), layout);
            let deltas = CoordDeltas {
                rows: vec![0, 0, 7, 13, 39],
                cols: vec![2, 5, 0, 3, 5],
                values: vec![4, -1, 2, 8, 3],
            };
            m.push_coords(&deltas).unwrap();
            let rows = [0u64, 7, 8, 13, 39];
            let dense = m.pull_rows(&rows).unwrap();
            let sparse = m.pull_sparse_rows(&rows).unwrap();
            assert_eq!(sparse.len(), rows.len());
            for (i, pairs) in sparse.iter().enumerate() {
                let mut densified = vec![0i64; 6];
                for &(c, v) in pairs {
                    assert_ne!(v, 0, "sparse pulls must not ship zeros");
                    densified[c as usize] = v;
                }
                assert_eq!(densified, dense[i * 6..(i + 1) * 6], "row {i} {layout:?}");
                // Columns ascend within a row.
                for w in pairs.windows(2) {
                    assert!(w[0].0 < w[1].0);
                }
            }
        }
    }

    #[test]
    fn topk_returns_k_largest_pairs() {
        let (_g, client) = setup(2, FaultPlan::reliable());
        let m: BigMatrix<i64> = client.matrix_with_layout(10, 8, Layout::Sparse).unwrap();
        let deltas = CoordDeltas {
            rows: vec![3, 3, 3, 3, 4],
            cols: vec![0, 2, 5, 7, 1],
            values: vec![5, 9, 2, 9, 1],
        };
        m.push_coords(&deltas).unwrap();
        let got = m.pull_topk(&[3, 4, 5], 2).unwrap();
        assert_eq!(got[0], vec![(2, 9), (7, 9)]);
        assert_eq!(got[1], vec![(1, 1)]);
        assert!(got[2].is_empty());
    }

    #[test]
    fn col_sums_match_client_side_sum() {
        let (_g, client) = setup(3, FaultPlan::reliable());
        let m: BigMatrix<i64> = client.matrix_with_layout(25, 4, Layout::Sparse).unwrap();
        let deltas = CoordDeltas {
            rows: (0..25).collect(),
            cols: (0..25).map(|i| (i % 4) as u32).collect(),
            values: (0..25).map(|i| i as i64 + 1).collect(),
        };
        m.push_coords(&deltas).unwrap();
        let sums = m.pull_col_sums().unwrap();
        let all: Vec<u64> = (0..25).collect();
        let full = m.pull_rows(&all).unwrap();
        let mut expect = vec![0i64; 4];
        for (i, &v) in full.iter().enumerate() {
            expect[i % 4] += v;
        }
        assert_eq!(sums, expect);
    }

    #[test]
    fn sparse_tickets_respect_bounds_and_empty() {
        let (_g, client) = setup(2, FaultPlan::reliable());
        let m: BigMatrix<i64> = client.matrix_with_layout(5, 2, Layout::Sparse).unwrap();
        assert!(m.pull_sparse_rows(&[5]).is_err());
        assert!(m.pull_topk(&[99], 3).is_err());
        assert_eq!(m.pull_sparse_rows(&[]).unwrap(), Vec::<Vec<(u32, i64)>>::new());
    }

    #[test]
    fn unavailable_pause_is_jittered_and_deterministic() {
        let (_g, client) = setup(1, FaultPlan::reliable());
        let ep = client.routes[0].eps[0].clone();
        let base = Duration::from_millis(100);
        let route = ShardRoute::new(vec![ep.clone()], 3, 42);
        let draws: Vec<Duration> = (0..32).map(|_| route.unavailable_pause(base)).collect();
        for d in &draws {
            assert!(*d >= base / 2 && *d < base * 3 / 2, "{d:?} outside jitter band");
        }
        assert!(draws.windows(2).any(|w| w[0] != w[1]), "jitter must vary across draws");
        assert_eq!(route.unavailable_retry_count(), 32);
        // Same seed, same sequence: replayable retry schedules.
        let route2 = ShardRoute::new(vec![ep], 3, 42);
        let draws2: Vec<Duration> = (0..32).map(|_| route2.unavailable_pause(base)).collect();
        assert_eq!(draws, draws2);
    }

    #[test]
    fn failed_ticket_reports_validation_error() {
        let (_g, client) = setup(2, FaultPlan::reliable());
        let m: BigMatrix<i64> = client.matrix(5, 2).unwrap();
        assert!(m.pull_rows_async(&[99]).wait().is_err());
        let bad = CoordDeltas { rows: vec![0], cols: vec![9], values: vec![1] };
        assert!(m.push_coords_async(&bad).wait().is_err());
        // A waited ticket consumed its error, so flush stays clean...
        client.flush().unwrap();
        // ...but a fire-and-forget invalid push must not vanish: its
        // validation error is parked for the next flush.
        let _ = m.push_coords_async(&bad);
        assert!(client.flush().is_err());
        client.flush().unwrap();
    }
}
