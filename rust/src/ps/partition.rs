//! Row partitioning schemes.
//!
//! The paper (§2.2) partitions matrices row-wise **cyclically**: row `r`
//! is stored on shard `r mod n` at local offset `r / n`. This is trivial
//! to compute, and — because the vocabulary is ordered by word frequency —
//! spreads the Zipfian head words evenly over shards (§3.2, Figure 5).
//!
//! A **range** scheme (contiguous blocks, what a naive implementation
//! would do) is provided as the comparison point for the Figure 5
//! reproduction.

/// How global rows map to (shard, local row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionScheme {
    /// Row `r` → shard `r mod n` (the paper's scheme).
    Cyclic,
    /// Row `r` → shard `floor(r * n / rows)` (contiguous blocks).
    Range,
}

impl PartitionScheme {
    /// Parse from CLI string.
    pub fn parse(s: &str) -> Option<PartitionScheme> {
        match s {
            "cyclic" => Some(PartitionScheme::Cyclic),
            "range" => Some(PartitionScheme::Range),
            _ => None,
        }
    }

    /// Wire tag (shard-info messages).
    pub fn tag(self) -> u8 {
        match self {
            PartitionScheme::Cyclic => 0,
            PartitionScheme::Range => 1,
        }
    }

    /// Inverse of [`PartitionScheme::tag`].
    pub fn from_tag(t: u8) -> Option<PartitionScheme> {
        match t {
            0 => Some(PartitionScheme::Cyclic),
            1 => Some(PartitionScheme::Range),
            _ => None,
        }
    }
}

/// A concrete partitioning of `rows` rows over `shards` shards.
#[derive(Debug, Clone, Copy)]
pub struct Partitioner {
    /// Total global rows.
    pub rows: u64,
    /// Number of shards.
    pub shards: usize,
    /// Mapping scheme.
    pub scheme: PartitionScheme,
}

impl Partitioner {
    /// Create a partitioner. `shards >= 1`.
    pub fn new(rows: u64, shards: usize, scheme: PartitionScheme) -> Partitioner {
        assert!(shards >= 1, "need at least one shard");
        Partitioner { rows, shards, scheme }
    }

    /// Shard that owns global row `row`.
    #[inline]
    pub fn shard_of(&self, row: u64) -> usize {
        debug_assert!(row < self.rows);
        match self.scheme {
            PartitionScheme::Cyclic => (row % self.shards as u64) as usize,
            PartitionScheme::Range => {
                // Boundaries are start(s) = floor(s * rows / shards);
                // floor(row * shards / rows) approximates the inverse but
                // can be off by one, so adjust against the real bounds.
                let mut s = (row as u128 * self.shards as u128 / self.rows.max(1) as u128)
                    as usize;
                s = s.min(self.shards - 1);
                while row < self.range_start(s) {
                    s -= 1;
                }
                while row >= self.range_start(s + 1) {
                    s += 1;
                }
                s
            }
        }
    }

    /// Local index of `row` within its owning shard.
    #[inline]
    pub fn local_index(&self, row: u64) -> u64 {
        match self.scheme {
            PartitionScheme::Cyclic => row / self.shards as u64,
            PartitionScheme::Range => row - self.range_start(self.shard_of(row)),
        }
    }

    /// Number of rows stored on `shard`.
    pub fn rows_on_shard(&self, shard: usize) -> u64 {
        match self.scheme {
            PartitionScheme::Cyclic => {
                let n = self.shards as u64;
                self.rows / n + u64::from((shard as u64) < self.rows % n)
            }
            PartitionScheme::Range => self.range_start(shard + 1) - self.range_start(shard),
        }
    }

    /// First global row of a range-scheme shard (also defined for
    /// `shard == shards`, where it returns `rows`).
    fn range_start(&self, shard: usize) -> u64 {
        (shard as u128 * self.rows as u128 / self.shards as u128) as u64
    }

    /// Reconstruct the global row id from `(shard, local)`.
    pub fn global_row(&self, shard: usize, local: u64) -> u64 {
        match self.scheme {
            PartitionScheme::Cyclic => local * self.shards as u64 + shard as u64,
            PartitionScheme::Range => self.range_start(shard) + local,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall_explain;

    #[test]
    fn cyclic_basics() {
        let p = Partitioner::new(10, 3, PartitionScheme::Cyclic);
        assert_eq!(p.shard_of(0), 0);
        assert_eq!(p.shard_of(1), 1);
        assert_eq!(p.shard_of(2), 2);
        assert_eq!(p.shard_of(3), 0);
        assert_eq!(p.local_index(3), 1);
        assert_eq!(p.rows_on_shard(0), 4); // rows 0,3,6,9
        assert_eq!(p.rows_on_shard(1), 3); // rows 1,4,7
        assert_eq!(p.rows_on_shard(2), 3); // rows 2,5,8
    }

    #[test]
    fn range_basics() {
        let p = Partitioner::new(10, 3, PartitionScheme::Range);
        assert_eq!(p.shard_of(0), 0);
        assert_eq!(p.shard_of(9), 2);
        let total: u64 = (0..3).map(|s| p.rows_on_shard(s)).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn parse_scheme() {
        assert_eq!(PartitionScheme::parse("cyclic"), Some(PartitionScheme::Cyclic));
        assert_eq!(PartitionScheme::parse("range"), Some(PartitionScheme::Range));
        assert_eq!(PartitionScheme::parse("zig"), None);
    }

    #[test]
    fn scheme_tag_roundtrips() {
        for s in [PartitionScheme::Cyclic, PartitionScheme::Range] {
            assert_eq!(PartitionScheme::from_tag(s.tag()), Some(s));
        }
        assert_eq!(PartitionScheme::from_tag(9), None);
    }

    /// Round-trip property: global → (shard, local) → global is identity,
    /// shard counts sum to total, local indices are dense per shard.
    #[test]
    fn partition_invariants_property() {
        forall_explain(
            "partition invariants",
            200,
            |rng| {
                let rows = 1 + rng.below(5000) as u64;
                let shards = 1 + rng.below(64);
                let scheme = if rng.bernoulli(0.5) {
                    PartitionScheme::Cyclic
                } else {
                    PartitionScheme::Range
                };
                (rows, shards, scheme)
            },
            |&(rows, shards, scheme)| {
                let p = Partitioner::new(rows, shards, scheme);
                let total: u64 = (0..shards).map(|s| p.rows_on_shard(s)).sum();
                if total != rows {
                    return Err(format!("shard sizes sum to {total}, want {rows}"));
                }
                let mut seen_local = vec![std::collections::HashSet::new(); shards];
                for r in 0..rows {
                    let s = p.shard_of(r);
                    if s >= shards {
                        return Err(format!("row {r} mapped to invalid shard {s}"));
                    }
                    let l = p.local_index(r);
                    if l >= p.rows_on_shard(s) {
                        return Err(format!(
                            "row {r}: local {l} >= shard size {}",
                            p.rows_on_shard(s)
                        ));
                    }
                    if p.global_row(s, l) != r {
                        return Err(format!("row {r} does not round-trip"));
                    }
                    if !seen_local[s].insert(l) {
                        return Err(format!("local index {l} on shard {s} duplicated"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn cyclic_balances_zipf_head() {
        // The motivating property (Figure 5): under cyclic partitioning of
        // a frequency-ordered vocabulary, adjacent high-frequency rows go
        // to different shards.
        let p = Partitioner::new(1000, 30, PartitionScheme::Cyclic);
        let shards: Vec<usize> = (0..30).map(|r| p.shard_of(r as u64)).collect();
        let uniq: std::collections::HashSet<_> = shards.iter().collect();
        assert_eq!(uniq.len(), 30, "top-30 words spread over all 30 shards");
    }
}
