//! In-memory shard storage: pluggable row layouts.
//!
//! The paper (§2.1) stores partial matrices as dense two-dimensional
//! arrays of JVM primitives in row-major order, chosen for fast random
//! updates and to avoid boxing/garbage-collection overhead. The rust
//! equivalent is [`DenseShard`]: a flat `Vec<T>` of `Copy` primitives —
//! contiguous, no indirection, no GC by construction.
//!
//! The word-topic matrix, however, is Zipf-shaped (§3, Figure 4): the
//! overwhelming majority of vocabulary rows have mass in only a handful
//! of topics. [`SparseShard`] stores each row as a sorted `(col, val)`
//! pair list, so resident bytes and sparse-pull payloads are
//! proportional to occupancy instead of `cols`. Rows whose fill crosses
//! [`PROMOTE_FILL`] (the Zipf head) are adaptively promoted to dense
//! slabs, keeping hot-row updates O(1).
//!
//! Both layouts expose the same operation set — dense reads, sparse
//! reads, per-row top-k, column sums, coordinate/row adds — so the
//! server's op executor is layout-agnostic.

use crate::util::error::{Error, Result};

/// Element bound shared by shard storage: the primitive kinds the wire
/// protocol ships (i64 counters, f32 weights).
pub trait StorageElement:
    Copy + Default + PartialEq + PartialOrd + std::ops::AddAssign + 'static
{
}

impl<T: Copy + Default + PartialEq + PartialOrd + std::ops::AddAssign + 'static> StorageElement
    for T
{
}

/// Order two values descending with a *total* order: `sort_unstable_by`
/// requires one, and mapping unordered (NaN) comparisons to `Equal`
/// would create cycles once the column tiebreak kicks in (a panic since
/// rust 1.81). NaNs form their own equivalence class ranked after every
/// ordered value, so they sink to the tail deterministically.
fn cmp_desc<T: PartialOrd>(a: &T, b: &T) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match b.partial_cmp(a) {
        Some(o) => o,
        None => {
            let a_unordered = a.partial_cmp(a).is_none();
            let b_unordered = b.partial_cmp(b).is_none();
            match (a_unordered, b_unordered) {
                (true, false) => Ordering::Greater,
                (false, true) => Ordering::Less,
                _ => Ordering::Equal,
            }
        }
    }
}

/// Select the top-`k` `(col, val)` pairs from `candidates` by value
/// descending, ties by column ascending; appends to the output vecs and
/// returns the number of pairs kept.
fn select_topk<T: StorageElement>(
    mut candidates: Vec<(u32, T)>,
    k: usize,
    cols_out: &mut Vec<u32>,
    vals_out: &mut Vec<T>,
) -> u32 {
    candidates.sort_unstable_by(|a, b| cmp_desc(&a.1, &b.1).then(a.0.cmp(&b.0)));
    candidates.truncate(k);
    let kept = candidates.len() as u32;
    for (c, v) in candidates {
        cols_out.push(c);
        vals_out.push(v);
    }
    kept
}

/// A shard's slice of one distributed matrix: `local_rows x cols`,
/// row-major, dense.
#[derive(Debug, Clone)]
pub struct DenseShard<T> {
    data: Vec<T>,
    local_rows: u64,
    cols: u32,
}

impl<T: StorageElement> DenseShard<T> {
    /// Allocate a zeroed shard.
    pub fn new(local_rows: u64, cols: u32) -> DenseShard<T> {
        let len = local_rows as usize * cols as usize;
        DenseShard { data: vec![T::default(); len], local_rows, cols }
    }

    /// Rows stored locally.
    pub fn local_rows(&self) -> u64 {
        self.local_rows
    }

    /// Columns (global — every shard stores full rows).
    pub fn cols(&self) -> u32 {
        self.cols
    }

    /// Bytes of payload storage.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<T>()
    }

    #[inline]
    fn offset(&self, local_row: u64, col: u32) -> Result<usize> {
        if local_row >= self.local_rows || col >= self.cols {
            return Err(Error::PsRejected(format!(
                "index ({local_row},{col}) out of bounds for {}x{} shard",
                self.local_rows, self.cols
            )));
        }
        Ok(local_row as usize * self.cols as usize + col as usize)
    }

    #[inline]
    fn check_row(&self, local_row: u64) -> Result<()> {
        if local_row >= self.local_rows {
            return Err(Error::PsRejected(format!(
                "row {local_row} out of bounds ({} rows)",
                self.local_rows
            )));
        }
        Ok(())
    }

    /// Read one entry.
    pub fn get(&self, local_row: u64, col: u32) -> Result<T> {
        Ok(self.data[self.offset(local_row, col)?])
    }

    /// Copy a full row into `out`.
    pub fn read_row(&self, local_row: u64, out: &mut Vec<T>) -> Result<()> {
        self.check_row(local_row)?;
        let start = local_row as usize * self.cols as usize;
        out.extend_from_slice(&self.data[start..start + self.cols as usize]);
        Ok(())
    }

    /// Append the row's non-default `(col, val)` pairs (columns
    /// ascending); returns the pair count.
    pub fn read_row_sparse(
        &self,
        local_row: u64,
        cols_out: &mut Vec<u32>,
        vals_out: &mut Vec<T>,
    ) -> Result<u32> {
        self.check_row(local_row)?;
        let start = local_row as usize * self.cols as usize;
        let mut n = 0u32;
        for (c, &v) in self.data[start..start + self.cols as usize].iter().enumerate() {
            if v != T::default() {
                cols_out.push(c as u32);
                vals_out.push(v);
                n += 1;
            }
        }
        Ok(n)
    }

    /// Append the row's top-`k` pairs by value descending (ties by
    /// column ascending); returns the pair count (`<= k`).
    pub fn read_row_topk(
        &self,
        local_row: u64,
        k: usize,
        cols_out: &mut Vec<u32>,
        vals_out: &mut Vec<T>,
    ) -> Result<u32> {
        self.check_row(local_row)?;
        let start = local_row as usize * self.cols as usize;
        let candidates: Vec<(u32, T)> = self.data[start..start + self.cols as usize]
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != T::default())
            .map(|(c, &v)| (c as u32, v))
            .collect();
        Ok(select_topk(candidates, k, cols_out, vals_out))
    }

    /// Sum every local row into `sums` (length `cols`).
    pub fn col_sums(&self, sums: &mut [T]) {
        debug_assert_eq!(sums.len(), self.cols as usize);
        for row in self.data.chunks_exact(self.cols.max(1) as usize) {
            for (s, &v) in sums.iter_mut().zip(row) {
                *s += v;
            }
        }
    }

    /// Add `delta` to one entry.
    pub fn add(&mut self, local_row: u64, col: u32, delta: T) -> Result<()> {
        let o = self.offset(local_row, col)?;
        self.data[o] += delta;
        Ok(())
    }

    /// Add a full row of deltas.
    pub fn add_row(&mut self, local_row: u64, deltas: &[T]) -> Result<()> {
        if deltas.len() != self.cols as usize {
            return Err(Error::PsRejected(format!(
                "row delta has {} entries, want {}",
                deltas.len(),
                self.cols
            )));
        }
        let start = self.offset(local_row, 0)?;
        for (slot, &d) in self.data[start..start + self.cols as usize].iter_mut().zip(deltas) {
            *slot += d;
        }
        Ok(())
    }

    /// Raw view of the shard (local_rows-major), for checkpoint rebuild
    /// verification in tests.
    pub fn raw(&self) -> &[T] {
        &self.data
    }
}

/// Fill fraction above which a sparse row promotes to a dense slab:
/// promote when `nnz * PROMOTE_FILL_DEN >= cols * PROMOTE_FILL_NUM`.
/// At 1/2 fill the pair list is already within ~25% of the slab's size
/// for i64 and costs a binary search per update; the slab wins on both.
const PROMOTE_FILL_NUM: usize = 1;
const PROMOTE_FILL_DEN: usize = 2;

/// One row of a [`SparseShard`].
#[derive(Debug, Clone)]
enum SparseRow<T> {
    /// Sorted-by-column `(col, val)` pairs; no default-valued entries.
    Pairs(Vec<(u32, T)>),
    /// Promoted dense slab (`cols` entries).
    Slab(Vec<T>),
}

/// A shard's slice of one distributed matrix stored sparsely: each row
/// is a sorted `(col, val)` pair list, adaptively promoted to a dense
/// slab once its fill crosses the promotion threshold.
#[derive(Debug, Clone)]
pub struct SparseShard<T> {
    rows: Vec<SparseRow<T>>,
    cols: u32,
}

impl<T: StorageElement> SparseShard<T> {
    /// Allocate an all-empty (all-zero) shard.
    pub fn new(local_rows: u64, cols: u32) -> SparseShard<T> {
        SparseShard { rows: vec![SparseRow::Pairs(Vec::new()); local_rows as usize], cols }
    }

    /// Rows stored locally.
    pub fn local_rows(&self) -> u64 {
        self.rows.len() as u64
    }

    /// Columns (global — every shard stores full rows).
    pub fn cols(&self) -> u32 {
        self.cols
    }

    /// Bytes of payload storage (pair lists + promoted slabs).
    pub fn bytes(&self) -> usize {
        let pair = std::mem::size_of::<(u32, T)>();
        self.rows
            .iter()
            .map(|r| match r {
                SparseRow::Pairs(p) => p.len() * pair,
                SparseRow::Slab(s) => s.len() * std::mem::size_of::<T>(),
            })
            .sum()
    }

    /// Non-default entries resident (slab rows count exactly).
    pub fn nnz(&self) -> u64 {
        self.rows
            .iter()
            .map(|r| match r {
                SparseRow::Pairs(p) => p.len() as u64,
                SparseRow::Slab(s) => s.iter().filter(|&&v| v != T::default()).count() as u64,
            })
            .sum()
    }

    /// Rows currently promoted to dense slabs.
    pub fn promoted_rows(&self) -> u64 {
        self.rows.iter().filter(|r| matches!(r, SparseRow::Slab(_))).count() as u64
    }

    #[inline]
    fn check(&self, local_row: u64, col: u32) -> Result<()> {
        if local_row >= self.local_rows() || col >= self.cols {
            return Err(Error::PsRejected(format!(
                "index ({local_row},{col}) out of bounds for {}x{} shard",
                self.local_rows(),
                self.cols
            )));
        }
        Ok(())
    }

    #[inline]
    fn check_row(&self, local_row: u64) -> Result<()> {
        if local_row >= self.local_rows() {
            return Err(Error::PsRejected(format!(
                "row {local_row} out of bounds ({} rows)",
                self.local_rows()
            )));
        }
        Ok(())
    }

    /// Read one entry (default where no pair exists).
    pub fn get(&self, local_row: u64, col: u32) -> Result<T> {
        self.check(local_row, col)?;
        Ok(match &self.rows[local_row as usize] {
            SparseRow::Pairs(p) => match p.binary_search_by_key(&col, |&(c, _)| c) {
                Ok(i) => p[i].1,
                Err(_) => T::default(),
            },
            SparseRow::Slab(s) => s[col as usize],
        })
    }

    /// Copy a full (densified) row into `out`.
    pub fn read_row(&self, local_row: u64, out: &mut Vec<T>) -> Result<()> {
        self.check_row(local_row)?;
        match &self.rows[local_row as usize] {
            SparseRow::Pairs(p) => {
                let start = out.len();
                out.resize(start + self.cols as usize, T::default());
                for &(c, v) in p {
                    out[start + c as usize] = v;
                }
            }
            SparseRow::Slab(s) => out.extend_from_slice(s),
        }
        Ok(())
    }

    /// Append the row's non-default `(col, val)` pairs (columns
    /// ascending); returns the pair count.
    pub fn read_row_sparse(
        &self,
        local_row: u64,
        cols_out: &mut Vec<u32>,
        vals_out: &mut Vec<T>,
    ) -> Result<u32> {
        self.check_row(local_row)?;
        match &self.rows[local_row as usize] {
            SparseRow::Pairs(p) => {
                for &(c, v) in p {
                    cols_out.push(c);
                    vals_out.push(v);
                }
                Ok(p.len() as u32)
            }
            SparseRow::Slab(s) => {
                let mut n = 0u32;
                for (c, &v) in s.iter().enumerate() {
                    if v != T::default() {
                        cols_out.push(c as u32);
                        vals_out.push(v);
                        n += 1;
                    }
                }
                Ok(n)
            }
        }
    }

    /// Append the row's top-`k` pairs by value descending (ties by
    /// column ascending); returns the pair count (`<= k`).
    pub fn read_row_topk(
        &self,
        local_row: u64,
        k: usize,
        cols_out: &mut Vec<u32>,
        vals_out: &mut Vec<T>,
    ) -> Result<u32> {
        self.check_row(local_row)?;
        let candidates: Vec<(u32, T)> = match &self.rows[local_row as usize] {
            SparseRow::Pairs(p) => p.clone(),
            SparseRow::Slab(s) => s
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != T::default())
                .map(|(c, &v)| (c as u32, v))
                .collect(),
        };
        Ok(select_topk(candidates, k, cols_out, vals_out))
    }

    /// Sum every local row into `sums` (length `cols`).
    pub fn col_sums(&self, sums: &mut [T]) {
        debug_assert_eq!(sums.len(), self.cols as usize);
        for row in &self.rows {
            match row {
                SparseRow::Pairs(p) => {
                    for &(c, v) in p {
                        sums[c as usize] += v;
                    }
                }
                SparseRow::Slab(s) => {
                    for (sum, &v) in sums.iter_mut().zip(s) {
                        *sum += v;
                    }
                }
            }
        }
    }

    /// Add `delta` to one entry; entries that land exactly on the
    /// default value are dropped from the pair list (counts that return
    /// to zero stop costing memory and bandwidth), and rows whose fill
    /// crosses the promotion threshold become dense slabs.
    pub fn add(&mut self, local_row: u64, col: u32, delta: T) -> Result<()> {
        self.check(local_row, col)?;
        if delta == T::default() {
            return Ok(());
        }
        let cols = self.cols as usize;
        let row = &mut self.rows[local_row as usize];
        match row {
            SparseRow::Pairs(p) => {
                match p.binary_search_by_key(&col, |&(c, _)| c) {
                    Ok(i) => {
                        p[i].1 += delta;
                        if p[i].1 == T::default() {
                            p.remove(i);
                        }
                    }
                    Err(i) => p.insert(i, (col, delta)),
                }
                if p.len() * PROMOTE_FILL_DEN >= cols * PROMOTE_FILL_NUM {
                    let mut slab = vec![T::default(); cols];
                    for &(c, v) in p.iter() {
                        slab[c as usize] = v;
                    }
                    *row = SparseRow::Slab(slab);
                }
            }
            SparseRow::Slab(s) => s[col as usize] += delta,
        }
        Ok(())
    }

    /// Add a full row of deltas: one O(cols) sorted merge of the pair
    /// list with the dense delta row (per-entry `add` would shift the
    /// vec on every insert — O(cols²) for a filling row, and this path
    /// carries the trainer's dense hot-word aggregates).
    pub fn add_row(&mut self, local_row: u64, deltas: &[T]) -> Result<()> {
        let cols = self.cols as usize;
        if deltas.len() != cols {
            return Err(Error::PsRejected(format!(
                "row delta has {} entries, want {}",
                deltas.len(),
                self.cols
            )));
        }
        self.check_row(local_row)?;
        let row = &mut self.rows[local_row as usize];
        let merged = match row {
            SparseRow::Slab(s) => {
                for (slot, &d) in s.iter_mut().zip(deltas) {
                    *slot += d;
                }
                return Ok(());
            }
            SparseRow::Pairs(p) => {
                let mut merged: Vec<(u32, T)> = Vec::with_capacity(p.len());
                let mut existing = p.iter().peekable();
                for (c, &d) in deltas.iter().enumerate() {
                    let c = c as u32;
                    let mut v = d;
                    if let Some(&&(pc, pv)) = existing.peek() {
                        if pc == c {
                            v += pv;
                            existing.next();
                        }
                    }
                    if v != T::default() {
                        merged.push((c, v));
                    }
                }
                merged
            }
        };
        if merged.len() * PROMOTE_FILL_DEN >= cols * PROMOTE_FILL_NUM {
            let mut slab = vec![T::default(); cols];
            for &(c, v) in &merged {
                slab[c as usize] = v;
            }
            *row = SparseRow::Slab(slab);
        } else {
            *row = SparseRow::Pairs(merged);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn zero_initialized() {
        let s: DenseShard<i64> = DenseShard::new(4, 3);
        for r in 0..4 {
            for c in 0..3 {
                assert_eq!(s.get(r, c).unwrap(), 0);
            }
        }
        assert_eq!(s.bytes(), 4 * 3 * 8);
    }

    #[test]
    fn add_and_get() {
        let mut s: DenseShard<i64> = DenseShard::new(2, 2);
        s.add(0, 1, 5).unwrap();
        s.add(0, 1, -2).unwrap();
        assert_eq!(s.get(0, 1).unwrap(), 3);
        assert_eq!(s.get(0, 0).unwrap(), 0);
    }

    #[test]
    fn add_row_and_read_row() {
        let mut s: DenseShard<f32> = DenseShard::new(3, 4);
        s.add_row(1, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        s.add_row(1, &[0.5, 0.5, 0.5, 0.5]).unwrap();
        let mut out = Vec::new();
        s.read_row(1, &mut out).unwrap();
        assert_eq!(out, vec![1.5, 2.5, 3.5, 4.5]);
    }

    #[test]
    fn bounds_checked() {
        let mut s: DenseShard<i64> = DenseShard::new(2, 2);
        assert!(s.get(2, 0).is_err());
        assert!(s.get(0, 2).is_err());
        assert!(s.add(5, 0, 1).is_err());
        assert!(s.add_row(0, &[1, 2, 3]).is_err());
        let mut out = Vec::new();
        assert!(s.read_row(9, &mut out).is_err());
    }

    #[test]
    fn zero_sized_shard() {
        let s: DenseShard<i64> = DenseShard::new(0, 10);
        assert_eq!(s.local_rows(), 0);
        assert_eq!(s.bytes(), 0);
    }

    #[test]
    fn dense_sparse_read_skips_zeros() {
        let mut s: DenseShard<i64> = DenseShard::new(1, 5);
        s.add(0, 1, 7).unwrap();
        s.add(0, 4, -3).unwrap();
        let (mut cols, mut vals) = (Vec::new(), Vec::new());
        let n = s.read_row_sparse(0, &mut cols, &mut vals).unwrap();
        assert_eq!(n, 2);
        assert_eq!(cols, vec![1, 4]);
        assert_eq!(vals, vec![7, -3]);
    }

    #[test]
    fn sparse_add_get_read_row() {
        let mut s: SparseShard<i64> = SparseShard::new(3, 100);
        s.add(1, 42, 5).unwrap();
        s.add(1, 7, 2).unwrap();
        s.add(1, 42, 1).unwrap();
        assert_eq!(s.get(1, 42).unwrap(), 6);
        assert_eq!(s.get(1, 0).unwrap(), 0);
        let mut out = Vec::new();
        s.read_row(1, &mut out).unwrap();
        assert_eq!(out.len(), 100);
        assert_eq!(out[7], 2);
        assert_eq!(out[42], 6);
        assert_eq!(out.iter().filter(|&&v| v != 0).count(), 2);
        assert_eq!(s.nnz(), 2);
    }

    #[test]
    fn sparse_entries_returning_to_zero_are_dropped() {
        let mut s: SparseShard<i64> = SparseShard::new(1, 10);
        s.add(0, 3, 4).unwrap();
        s.add(0, 3, -4).unwrap();
        assert_eq!(s.nnz(), 0);
        assert_eq!(s.bytes(), 0);
        assert_eq!(s.get(0, 3).unwrap(), 0);
    }

    #[test]
    fn sparse_promotes_to_dense_above_fill_threshold() {
        let cols = 16u32;
        let mut s: SparseShard<i64> = SparseShard::new(2, cols);
        // Fill row 0 past half occupancy; row 1 stays sparse.
        for c in 0..cols {
            s.add(0, c, 1).unwrap();
        }
        s.add(1, 3, 1).unwrap();
        assert_eq!(s.promoted_rows(), 1);
        // Semantics unchanged after promotion.
        for c in 0..cols {
            assert_eq!(s.get(0, c).unwrap(), 1);
        }
        let (mut pc, mut pv) = (Vec::new(), Vec::new());
        assert_eq!(s.read_row_sparse(0, &mut pc, &mut pv).unwrap(), cols);
        assert_eq!(s.get(1, 3).unwrap(), 1);
        assert_eq!(s.promoted_rows(), 1);
    }

    #[test]
    fn sparse_matches_dense_reference_randomized() {
        let mut rng = Pcg64::new(0x57a);
        for case in 0..20 {
            let rows = 1 + rng.below(8) as u64;
            let cols = 1 + rng.below(24) as u32;
            let mut dense: DenseShard<i64> = DenseShard::new(rows, cols);
            let mut sparse: SparseShard<i64> = SparseShard::new(rows, cols);
            for _ in 0..200 {
                let r = rng.below(rows as usize) as u64;
                let c = rng.below(cols as usize) as u32;
                let v = rng.below(7) as i64 - 3;
                dense.add(r, c, v).unwrap();
                sparse.add(r, c, v).unwrap();
            }
            for r in 0..rows {
                let (mut dv, mut sv) = (Vec::new(), Vec::new());
                dense.read_row(r, &mut dv).unwrap();
                sparse.read_row(r, &mut sv).unwrap();
                assert_eq!(dv, sv, "row {r} case {case}");
                let (mut dc, mut dvals) = (Vec::new(), Vec::new());
                let (mut sc, mut svals) = (Vec::new(), Vec::new());
                dense.read_row_sparse(r, &mut dc, &mut dvals).unwrap();
                sparse.read_row_sparse(r, &mut sc, &mut svals).unwrap();
                assert_eq!((dc, dvals), (sc, svals), "sparse read row {r} case {case}");
            }
            let mut dsums = vec![0i64; cols as usize];
            let mut ssums = vec![0i64; cols as usize];
            dense.col_sums(&mut dsums);
            sparse.col_sums(&mut ssums);
            assert_eq!(dsums, ssums, "col sums case {case}");
        }
    }

    #[test]
    fn topk_orders_by_value_then_col() {
        let mut s: SparseShard<i64> = SparseShard::new(1, 50);
        s.add(0, 10, 5).unwrap();
        s.add(0, 3, 9).unwrap();
        s.add(0, 20, 5).unwrap();
        s.add(0, 30, 1).unwrap();
        let (mut cols, mut vals) = (Vec::new(), Vec::new());
        let n = s.read_row_topk(0, 3, &mut cols, &mut vals).unwrap();
        assert_eq!(n, 3);
        assert_eq!(cols, vec![3, 10, 20]);
        assert_eq!(vals, vec![9, 5, 5]);
        // k larger than occupancy returns everything.
        let (mut cols, mut vals) = (Vec::new(), Vec::new());
        assert_eq!(s.read_row_topk(0, 100, &mut cols, &mut vals).unwrap(), 4);
    }

    #[test]
    fn topk_with_nan_values_does_not_panic() {
        // The comparator must stay a total order even with NaNs in the
        // row (sort_unstable_by panics on non-total comparators).
        let mut s: DenseShard<f32> = DenseShard::new(1, 6);
        s.add(0, 0, 1.0).unwrap();
        s.add(0, 1, f32::NAN).unwrap();
        s.add(0, 2, 2.0).unwrap();
        s.add(0, 3, f32::NAN).unwrap();
        s.add(0, 4, 0.5).unwrap();
        let (mut cols, mut vals) = (Vec::new(), Vec::new());
        let n = s.read_row_topk(0, 3, &mut cols, &mut vals).unwrap();
        assert_eq!(n, 3);
        // Ordered values rank first (descending); NaNs sink to the tail.
        assert_eq!(cols, vec![2, 0, 4]);
        assert_eq!(vals, vec![2.0, 1.0, 0.5]);
    }

    #[test]
    fn sparse_add_row_merges_with_existing_pairs() {
        let mut s: SparseShard<i64> = SparseShard::new(1, 8);
        s.add(0, 2, 5).unwrap();
        s.add(0, 6, 1).unwrap();
        s.add_row(0, &[1, 0, -5, 0, 0, 0, 2, 0]).unwrap();
        let mut out = Vec::new();
        s.read_row(0, &mut out).unwrap();
        assert_eq!(out, vec![1, 0, 0, 0, 0, 0, 3, 0]);
        // (2, 5) + (-5) cancelled to zero and was dropped.
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.promoted_rows(), 0);
    }

    #[test]
    fn sparse_add_row_and_bounds() {
        let mut s: SparseShard<i64> = SparseShard::new(2, 4);
        s.add_row(0, &[1, 0, -2, 0]).unwrap();
        let mut out = Vec::new();
        s.read_row(0, &mut out).unwrap();
        assert_eq!(out, vec![1, 0, -2, 0]);
        assert!(s.add_row(0, &[1, 2]).is_err());
        assert!(s.add(2, 0, 1).is_err());
        assert!(s.add(0, 4, 1).is_err());
        let mut out = Vec::new();
        assert!(s.read_row(5, &mut out).is_err());
        assert!(s.read_row_sparse(5, &mut Vec::new(), &mut Vec::new()).is_err());
        assert!(s.read_row_topk(5, 1, &mut Vec::new(), &mut Vec::new()).is_err());
    }
}
