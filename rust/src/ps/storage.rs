//! Dense in-memory shard storage.
//!
//! The paper (§2.1) stores partial matrices as dense two-dimensional
//! arrays of JVM primitives in row-major order, chosen for fast random
//! updates and to avoid boxing/garbage-collection overhead. The rust
//! equivalent is a flat `Vec<T>` of `Copy` primitives — contiguous, no
//! indirection, no GC by construction.

use crate::util::error::{Error, Result};

/// A shard's slice of one distributed matrix: `local_rows x cols`,
/// row-major.
#[derive(Debug, Clone)]
pub struct DenseShard<T> {
    data: Vec<T>,
    local_rows: u64,
    cols: u32,
}

impl<T: Copy + Default + std::ops::AddAssign> DenseShard<T> {
    /// Allocate a zeroed shard.
    pub fn new(local_rows: u64, cols: u32) -> DenseShard<T> {
        let len = local_rows as usize * cols as usize;
        DenseShard { data: vec![T::default(); len], local_rows, cols }
    }

    /// Rows stored locally.
    pub fn local_rows(&self) -> u64 {
        self.local_rows
    }

    /// Columns (global — every shard stores full rows).
    pub fn cols(&self) -> u32 {
        self.cols
    }

    /// Bytes of payload storage.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<T>()
    }

    #[inline]
    fn offset(&self, local_row: u64, col: u32) -> Result<usize> {
        if local_row >= self.local_rows || col >= self.cols {
            return Err(Error::PsRejected(format!(
                "index ({local_row},{col}) out of bounds for {}x{} shard",
                self.local_rows, self.cols
            )));
        }
        Ok(local_row as usize * self.cols as usize + col as usize)
    }

    /// Read one entry.
    pub fn get(&self, local_row: u64, col: u32) -> Result<T> {
        Ok(self.data[self.offset(local_row, col)?])
    }

    /// Copy a full row into `out`.
    pub fn read_row(&self, local_row: u64, out: &mut Vec<T>) -> Result<()> {
        if local_row >= self.local_rows {
            return Err(Error::PsRejected(format!(
                "row {local_row} out of bounds ({} rows)",
                self.local_rows
            )));
        }
        let start = local_row as usize * self.cols as usize;
        out.extend_from_slice(&self.data[start..start + self.cols as usize]);
        Ok(())
    }

    /// Add `delta` to one entry.
    pub fn add(&mut self, local_row: u64, col: u32, delta: T) -> Result<()> {
        let o = self.offset(local_row, col)?;
        self.data[o] += delta;
        Ok(())
    }

    /// Add a full row of deltas.
    pub fn add_row(&mut self, local_row: u64, deltas: &[T]) -> Result<()> {
        if deltas.len() != self.cols as usize {
            return Err(Error::PsRejected(format!(
                "row delta has {} entries, want {}",
                deltas.len(),
                self.cols
            )));
        }
        let start = self.offset(local_row, 0)?;
        for (slot, &d) in self.data[start..start + self.cols as usize].iter_mut().zip(deltas) {
            *slot += d;
        }
        Ok(())
    }

    /// Raw view of the shard (local_rows-major), for checkpoint rebuild
    /// verification in tests.
    pub fn raw(&self) -> &[T] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_initialized() {
        let s: DenseShard<i64> = DenseShard::new(4, 3);
        for r in 0..4 {
            for c in 0..3 {
                assert_eq!(s.get(r, c).unwrap(), 0);
            }
        }
        assert_eq!(s.bytes(), 4 * 3 * 8);
    }

    #[test]
    fn add_and_get() {
        let mut s: DenseShard<i64> = DenseShard::new(2, 2);
        s.add(0, 1, 5).unwrap();
        s.add(0, 1, -2).unwrap();
        assert_eq!(s.get(0, 1).unwrap(), 3);
        assert_eq!(s.get(0, 0).unwrap(), 0);
    }

    #[test]
    fn add_row_and_read_row() {
        let mut s: DenseShard<f32> = DenseShard::new(3, 4);
        s.add_row(1, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        s.add_row(1, &[0.5, 0.5, 0.5, 0.5]).unwrap();
        let mut out = Vec::new();
        s.read_row(1, &mut out).unwrap();
        assert_eq!(out, vec![1.5, 2.5, 3.5, 4.5]);
    }

    #[test]
    fn bounds_checked() {
        let mut s: DenseShard<i64> = DenseShard::new(2, 2);
        assert!(s.get(2, 0).is_err());
        assert!(s.get(0, 2).is_err());
        assert!(s.add(5, 0, 1).is_err());
        assert!(s.add_row(0, &[1, 2, 3]).is_err());
        let mut out = Vec::new();
        assert!(s.read_row(9, &mut out).is_err());
    }

    #[test]
    fn zero_sized_shard() {
        let s: DenseShard<i64> = DenseShard::new(0, 10);
        assert_eq!(s.local_rows(), 0);
        assert_eq!(s.bytes(), 0);
    }
}
