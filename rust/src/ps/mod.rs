//! **Glint** — the asynchronous parameter server (the paper's §2).
//!
//! A parameter server stores large matrices and vectors partitioned across
//! shard servers and exposes exactly two operations to users:
//!
//! - **pull** — fetch entries (rows of a matrix, slices of a vector);
//! - **push** — apply additive deltas to entries.
//!
//! Because addition is commutative and associative, pushes need no
//! locking or conflict resolution: deltas may be applied in any order
//! (paper §2.5). What *does* need care is delivery semantics: the
//! underlying transport is at-most-once, so
//!
//! - pulls are retried with **exponential back-off** until a response
//!   arrives (they are read-only, so retries are harmless — §2.3);
//! - pushes use a **three-phase hand-shake** (acquire unique id → push
//!   with id, retrying until acknowledged → forget id) so that every
//!   delta is applied **exactly once** even under message loss and
//!   duplication (§2.4, Figure 2).
//!
//! Matrices are partitioned **row-wise cyclically** ([`partition`]):
//! row `r` lives on shard `r mod n`. Combined with a frequency-ordered
//! vocabulary this yields the implicit load-balancing property of §3.2.
//!
//! The user-facing handles are [`client::BigMatrix`] and
//! [`client::BigVector`], which act on a *virtual view* of the matrix —
//! callers never see where data physically lives (paper Figure 1).

pub mod client;
pub mod config;
pub mod messages;
pub mod partition;
pub mod server;
pub mod storage;

pub use client::{BigMatrix, BigVector, PsClient, SparseRow, Ticket};
pub use config::PsConfig;
pub use messages::{Data, Dtype, Layout, Request, Response, SparseData};
pub use partition::{PartitionScheme, Partitioner};
pub use server::ServerGroup;
