//! Wire messages between parameter-server clients and shard servers.
//!
//! Every request/response is byte-serialized via [`crate::util::codec`],
//! both to keep the transport payload-agnostic and so that measured
//! message sizes match what a real deployment would put on the wire
//! (the paper sizes its push buffers at ~2 MB, §3.3).

use crate::ps::partition::PartitionScheme;
use crate::util::codec::{Reader, Writer};
use crate::util::error::{Error, Result};

/// Element type of a distributed matrix/vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// 64-bit signed counters (Gibbs count tables).
    I64,
    /// 32-bit floats (weight vectors, e.g. logistic regression).
    F32,
}

impl Dtype {
    fn tag(self) -> u8 {
        match self {
            Dtype::I64 => 0,
            Dtype::F32 => 1,
        }
    }

    fn from_tag(t: u8) -> Result<Dtype> {
        match t {
            0 => Ok(Dtype::I64),
            1 => Ok(Dtype::F32),
            _ => Err(Error::Decode(format!("bad dtype tag {t}"))),
        }
    }
}

/// Physical storage layout of a matrix's shard slices.
///
/// Declared at [`Request::CreateMatrix`] time and honored by every
/// shard: `Dense` backs rows with contiguous `cols`-length slabs (fast
/// random updates, the paper's §2.1 choice); `Sparse` backs rows with
/// sorted `(col, val)` pair lists that adaptively promote to dense
/// above a fill threshold — the right shape for Zipfian word-topic
/// matrices where most vocabulary rows touch a handful of topics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Layout {
    /// Row-major dense slabs.
    #[default]
    Dense,
    /// Per-row sorted `(col, val)` pairs with adaptive dense promotion.
    Sparse,
}

impl Layout {
    /// Wire tag.
    pub fn tag(self) -> u8 {
        match self {
            Layout::Dense => 0,
            Layout::Sparse => 1,
        }
    }

    /// Inverse of [`Layout::tag`].
    pub fn from_tag(t: u8) -> Result<Layout> {
        match t {
            0 => Ok(Layout::Dense),
            1 => Ok(Layout::Sparse),
            _ => Err(Error::Decode(format!("bad layout tag {t}"))),
        }
    }

    /// Parse a CLI/env layout name (`dense` | `sparse`).
    pub fn parse(s: &str) -> Option<Layout> {
        match s {
            "dense" => Some(Layout::Dense),
            "sparse" => Some(Layout::Sparse),
            _ => None,
        }
    }
}

/// A typed payload of matrix values.
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    /// i64 values.
    I64(Vec<i64>),
    /// f32 values.
    F32(Vec<f32>),
}

impl Data {
    /// Number of scalar values.
    pub fn len(&self) -> usize {
        match self {
            Data::I64(v) => v.len(),
            Data::F32(v) => v.len(),
        }
    }

    /// True when no values.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Element dtype.
    pub fn dtype(&self) -> Dtype {
        match self {
            Data::I64(_) => Dtype::I64,
            Data::F32(_) => Dtype::F32,
        }
    }

    pub(crate) fn encode(&self, w: &mut Writer) {
        match self {
            Data::I64(v) => {
                w.u8(Dtype::I64.tag());
                w.slice_i64(v);
            }
            Data::F32(v) => {
                w.u8(Dtype::F32.tag());
                w.slice_f32(v);
            }
        }
    }

    pub(crate) fn decode(r: &mut Reader) -> Result<Data> {
        match Dtype::from_tag(r.u8()?)? {
            Dtype::I64 => Ok(Data::I64(r.slice_i64()?)),
            Dtype::F32 => Ok(Data::F32(r.slice_f32()?)),
        }
    }

    /// Compact encoding for sparse payloads: i64 count values are almost
    /// always tiny, so they go out as zigzag varints (~1 byte) instead
    /// of fixed 8-byte words; f32 has no cheap variable-width form and
    /// stays raw.
    fn encode_compact(&self, w: &mut Writer) {
        match self {
            Data::I64(v) => {
                w.u8(Dtype::I64.tag());
                w.slice_zigzag(v);
            }
            Data::F32(v) => {
                w.u8(Dtype::F32.tag());
                w.slice_f32(v);
            }
        }
    }

    fn decode_compact(r: &mut Reader) -> Result<Data> {
        match Dtype::from_tag(r.u8()?)? {
            Dtype::I64 => Ok(Data::I64(r.slice_zigzag()?)),
            Dtype::F32 => Ok(Data::F32(r.slice_f32()?)),
        }
    }
}

/// Sparse row payload: for a set of requested rows, the per-row pair
/// counts plus the concatenated `(col, value)` pairs in request order.
///
/// Columns ride as varints (bounded by K, usually one byte) and i64
/// values as zigzag varints, so a Zipf-tail row costs a few bytes
/// instead of a full `cols`-length slab.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseData {
    /// Number of `(col, value)` pairs for each requested row, in
    /// request order.
    pub lens: Vec<u32>,
    /// Concatenated column ids. Within each row the order is
    /// op-defined: strictly ascending for sparse pulls, value-descending
    /// (ties by ascending column) for top-k replies.
    pub cols: Vec<u32>,
    /// Concatenated values, `cols.len()` entries.
    pub values: Data,
}

impl SparseData {
    /// Total `(col, value)` pairs.
    pub fn pairs(&self) -> usize {
        self.cols.len()
    }

    /// Validate internal consistency (lengths agree).
    pub fn check(&self) -> Result<()> {
        let total: u64 = self.lens.iter().map(|&l| l as u64).sum();
        if total != self.cols.len() as u64 || self.cols.len() != self.values.len() {
            return Err(Error::Decode(format!(
                "sparse payload inconsistent: lens sum {total}, {} cols, {} values",
                self.cols.len(),
                self.values.len()
            )));
        }
        Ok(())
    }

    fn encode(&self, w: &mut Writer) {
        w.slice_varint_u32(&self.lens);
        w.slice_varint_u32(&self.cols);
        self.values.encode_compact(w);
    }

    fn decode(r: &mut Reader) -> Result<SparseData> {
        let data = SparseData {
            lens: r.slice_varint_u32()?,
            cols: r.slice_varint_u32()?,
            values: Data::decode_compact(r)?,
        };
        data.check()?;
        Ok(data)
    }
}

/// Client → shard server requests.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Allocate this shard's slice of a new matrix (broadcast to all
    /// shards). Vectors are matrices with `cols == 1`.
    CreateMatrix {
        /// Matrix id (client-assigned, globally unique).
        id: u32,
        /// Global row count.
        rows: u64,
        /// Column count.
        cols: u32,
        /// Element type.
        dtype: Dtype,
        /// Shard storage layout.
        layout: Layout,
    },
    /// Read full rows (global row ids owned by this shard).
    PullRows {
        /// Matrix id.
        id: u32,
        /// Global row indices.
        rows: Vec<u64>,
    },
    /// Read rows as `(col, value)` pairs (non-default entries only) —
    /// the bandwidth-proportional-to-occupancy pull for Zipf-shaped
    /// matrices. Works on either layout.
    PullSparseRows {
        /// Matrix id.
        id: u32,
        /// Global row indices.
        rows: Vec<u64>,
    },
    /// Server-side top-k per row: the `k` largest `(col, value)` pairs
    /// of each requested row, by value descending (ties by column
    /// ascending). Topic inspection without shipping full rows.
    PullTopK {
        /// Matrix id.
        id: u32,
        /// Global row indices.
        rows: Vec<u64>,
        /// Pairs to keep per row.
        k: u32,
    },
    /// Server-side aggregation: the column sums over every local row of
    /// this shard. Summing the per-shard replies client-side yields the
    /// global column totals (for LDA: the topic-count vector) without
    /// pulling the matrix.
    PullColSums {
        /// Matrix id.
        id: u32,
    },
    /// Phase 1 of the push hand-shake: acquire a unique push id.
    /// Idempotent to retry — an orphaned id is never pushed and costs one
    /// set entry until forgotten by GC (never, in this model; ids are
    /// only recorded once *used*).
    GenUid,
    /// Phase 2: apply sparse additive deltas under `uid`. Retrying is
    /// safe: a shard applies a given `uid` at most once.
    PushCoords {
        /// Matrix id.
        id: u32,
        /// Unique push id from [`Request::GenUid`].
        uid: u64,
        /// Global row per delta.
        rows: Vec<u64>,
        /// Column per delta.
        cols: Vec<u32>,
        /// Delta values (same length).
        values: Data,
    },
    /// Phase 2 (dense form): add full-row deltas under `uid`.
    PushRows {
        /// Matrix id.
        id: u32,
        /// Unique push id.
        uid: u64,
        /// Global rows, one per `cols`-sized chunk of `values`.
        rows: Vec<u64>,
        /// Row-major delta values, `rows.len() * cols` entries.
        values: Data,
    },
    /// Phase 3: the push was acknowledged; the server may drop its
    /// dedup record for `uid`. Idempotent.
    Forget {
        /// Push id to release.
        uid: u64,
    },
    /// Drop a whole matrix and reclaim its memory (and, with a WAL, its
    /// log bytes at the next compaction). Broadcast to all shards; used
    /// by the coordinator to fence off contaminated epoch tables.
    DeleteMatrix {
        /// Matrix id to drop. Deleting an unknown id is a no-op.
        matrix: u32,
    },
    /// Replication: a backup asks its primary for committed WAL records
    /// starting at sequence `from`. Served from the read pool.
    ReplPoll {
        /// First sequence number wanted (1 on a cold start).
        from: u64,
    },
    /// Promote a backup shard to primary (issued by the coordinator
    /// when the primary goes silent). Idempotent. With a chain of
    /// backups the coordinator walks the chain head-ward and promotes
    /// the first live replica.
    Promote,
    /// Replication: apply a batch of WAL records to a backup. `reset`
    /// means the records are a full snapshot and existing state must be
    /// discarded first. Applied through the same dedup path as live
    /// pushes, so re-delivery is safe.
    ReplApply {
        /// Replication generation the batch was fetched under. A
        /// [`Request::ReplSeed`] bumps the replica's generation, so a
        /// poller batch fetched from the *previous* upstream — a zombie
        /// primary's log racing the re-seed — is fenced off instead of
        /// corrupting the freshly seeded state.
        gen: u64,
        /// Discard current state before applying (snapshot batch).
        reset: bool,
        /// The primary's committed tip at poll time, so the backup can
        /// report how far it trails (`Info::repl_lag`).
        tip: u64,
        /// `(seq, wal payload bytes)` in order.
        records: Vec<(u64, Vec<u8>)>,
    },
    /// Replication: re-seed a backup behind a (possibly new) upstream
    /// mid-run. The records are the upstream's newest snapshot slice
    /// (the same shape a reset `ReplBatch` carries); the backup rebuilds
    /// from them, bumps its replication generation (fencing any batch
    /// still in flight from the old upstream), and re-points its poller
    /// at `upstream` to tail the remaining log through the normal
    /// `ReplPoll` path. This is how a deployment regains redundancy
    /// after a promotion without pausing training.
    ReplSeed {
        /// Address of the upstream to tail after seeding; empty keeps
        /// the currently configured upstream.
        upstream: String,
        /// The upstream's committed tip when the seed was taken.
        tip: u64,
        /// `(seq, wal payload bytes)`: the upstream's snapshot slice.
        records: Vec<(u64, Vec<u8>)>,
    },
    /// Planned hand-off: stop accepting data ops (they get the retryable
    /// [`Response::Unavailable`]), fsync the WAL, and report the
    /// committed tip so the coordinator can wait for a backup to fully
    /// catch up before promoting it — a hand-off that loses nothing and
    /// therefore needs no epoch roll. Idempotent.
    Drain,
    /// Shard introspection (row count, bytes, matrices).
    ShardInfo,
    /// Stop the shard server thread.
    Shutdown,
}

/// Shard server → client responses.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Generic success.
    Ok,
    /// Unique push id (phase 1 reply).
    Uid(u64),
    /// Pulled row values, concatenated in request order.
    Rows(Data),
    /// Pulled sparse rows (or top-k pairs), in request order.
    SparseRows(SparseData),
    /// Push applied (`fresh == true`) or deduplicated (`fresh == false`).
    PushAck {
        /// Whether this delivery performed the mutation.
        fresh: bool,
    },
    /// Shard statistics and deployment layout (lets clients validate
    /// their shard count / scheme / address order against the server's).
    Info {
        /// This server's global shard id.
        shard_id: u32,
        /// Total shards in the server's deployment.
        shards: u32,
        /// Row partitioning scheme the server applies.
        scheme: PartitionScheme,
        /// Matrices hosted.
        matrices: u32,
        /// Total local rows across matrices.
        local_rows: u64,
        /// Payload bytes resident.
        bytes: u64,
        /// Outstanding (un-forgotten) push uids.
        pending_uids: u64,
        /// Dedup records evicted by the bounded window before their
        /// `Forget` arrived (each is a client that died mid-hand-shake;
        /// a retry after eviction would re-apply).
        dedup_evictions: u64,
        /// Replication role: 0 = primary, 1 = backup, 2 = promoted
        /// backup now serving as primary.
        role: u8,
        /// WAL records appended (0 when the WAL is off).
        wal_records: u64,
        /// WAL bytes resident on disk.
        wal_bytes: u64,
        /// Group-commit fsync batches written.
        wal_commit_batches: u64,
        /// Replication: WAL sequences applied on this replica.
        repl_applied: u64,
        /// Replication: primary's committed tip minus `repl_applied`
        /// at the last poll (how far this replica trails).
        repl_lag: u64,
    },
    /// Replication batch (reply to [`Request::ReplPoll`]); mirrors
    /// `wal::WalSlice`.
    ReplBatch {
        /// Records are a full snapshot; rebuild from scratch.
        reset: bool,
        /// Cursor for the next poll.
        next: u64,
        /// Primary's committed tip at read time.
        tip: u64,
        /// `(seq, wal payload bytes)` in order.
        records: Vec<(u64, Vec<u8>)>,
    },
    /// Answer to [`Request::Drain`]: the WAL is fsynced and the shard
    /// now refuses data ops, so every write acked before the drain is
    /// at or below `tip` — a backup whose `repl_applied` reaches `tip`
    /// holds the complete commit window.
    Drained {
        /// The draining shard's committed WAL tip.
        tip: u64,
    },
    /// The shard cannot serve this request in its current role (e.g. a
    /// data op sent to an un-promoted backup). Unlike
    /// [`Response::Error`], this is retryable — the client's courier
    /// treats it as a failure and advances its failover route.
    Unavailable(String),
    /// Request failed server-side.
    Error(String),
}

// --- encoding ----------------------------------------------------------

const T_CREATE: u8 = 1;
const T_PULL_ROWS: u8 = 2;
const T_GEN_UID: u8 = 3;
const T_PUSH_COORDS: u8 = 4;
const T_PUSH_ROWS: u8 = 5;
const T_FORGET: u8 = 6;
const T_INFO: u8 = 7;
const T_SHUTDOWN: u8 = 8;
const T_PULL_SPARSE_ROWS: u8 = 9;
const T_PULL_TOPK: u8 = 10;
const T_PULL_COL_SUMS: u8 = 11;
const T_DELETE_MATRIX: u8 = 12;
const T_REPL_POLL: u8 = 13;
const T_PROMOTE: u8 = 14;
const T_REPL_APPLY: u8 = 15;
const T_REPL_SEED: u8 = 16;
const T_DRAIN: u8 = 17;

/// Encode `(seq, payload)` record lists shared by `ReplApply` and
/// `ReplBatch`.
fn encode_records(w: &mut Writer, records: &[(u64, Vec<u8>)]) {
    w.usize(records.len());
    for (seq, payload) in records {
        w.u64(*seq);
        w.bytes(payload);
    }
}

fn decode_records(r: &mut Reader) -> Result<Vec<(u64, Vec<u8>)>> {
    let n = r.usize()?;
    let mut records = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        records.push((r.u64()?, r.bytes()?));
    }
    Ok(records)
}

impl Request {
    /// Serialize to wire bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            Request::CreateMatrix { id, rows, cols, dtype, layout } => {
                w.u8(T_CREATE);
                w.u32(*id);
                w.u64(*rows);
                w.u32(*cols);
                w.u8(dtype.tag());
                w.u8(layout.tag());
            }
            Request::PullRows { id, rows } => {
                w.u8(T_PULL_ROWS);
                w.u32(*id);
                w.slice_varint(rows);
            }
            Request::PullSparseRows { id, rows } => {
                w.u8(T_PULL_SPARSE_ROWS);
                w.u32(*id);
                w.slice_varint(rows);
            }
            Request::PullTopK { id, rows, k } => {
                w.u8(T_PULL_TOPK);
                w.u32(*id);
                w.slice_varint(rows);
                w.u32(*k);
            }
            Request::PullColSums { id } => {
                w.u8(T_PULL_COL_SUMS);
                w.u32(*id);
            }
            Request::GenUid => w.u8(T_GEN_UID),
            Request::PushCoords { id, uid, rows, cols, values } => {
                w.u8(T_PUSH_COORDS);
                w.u32(*id);
                w.u64(*uid);
                w.slice_varint(rows);
                w.slice_u32(cols);
                values.encode(&mut w);
            }
            Request::PushRows { id, uid, rows, values } => {
                w.u8(T_PUSH_ROWS);
                w.u32(*id);
                w.u64(*uid);
                w.slice_varint(rows);
                values.encode(&mut w);
            }
            Request::Forget { uid } => {
                w.u8(T_FORGET);
                w.u64(*uid);
            }
            Request::DeleteMatrix { matrix } => {
                w.u8(T_DELETE_MATRIX);
                w.u32(*matrix);
            }
            Request::ReplPoll { from } => {
                w.u8(T_REPL_POLL);
                w.u64(*from);
            }
            Request::Promote => w.u8(T_PROMOTE),
            Request::ReplApply { gen, reset, tip, records } => {
                w.u8(T_REPL_APPLY);
                w.u64(*gen);
                w.u8(u8::from(*reset));
                w.u64(*tip);
                encode_records(&mut w, records);
            }
            Request::ReplSeed { upstream, tip, records } => {
                w.u8(T_REPL_SEED);
                w.str(upstream);
                w.u64(*tip);
                encode_records(&mut w, records);
            }
            Request::Drain => w.u8(T_DRAIN),
            Request::ShardInfo => w.u8(T_INFO),
            Request::Shutdown => w.u8(T_SHUTDOWN),
        }
        w.into_bytes()
    }

    /// Parse from wire bytes.
    pub fn decode(bytes: &[u8]) -> Result<Request> {
        let mut r = Reader::new(bytes);
        let req = match r.u8()? {
            T_CREATE => Request::CreateMatrix {
                id: r.u32()?,
                rows: r.u64()?,
                cols: r.u32()?,
                dtype: Dtype::from_tag(r.u8()?)?,
                layout: Layout::from_tag(r.u8()?)?,
            },
            T_PULL_ROWS => Request::PullRows { id: r.u32()?, rows: r.slice_varint()? },
            T_PULL_SPARSE_ROWS => {
                Request::PullSparseRows { id: r.u32()?, rows: r.slice_varint()? }
            }
            T_PULL_TOPK => {
                Request::PullTopK { id: r.u32()?, rows: r.slice_varint()?, k: r.u32()? }
            }
            T_PULL_COL_SUMS => Request::PullColSums { id: r.u32()? },
            T_GEN_UID => Request::GenUid,
            T_PUSH_COORDS => Request::PushCoords {
                id: r.u32()?,
                uid: r.u64()?,
                rows: r.slice_varint()?,
                cols: r.slice_u32()?,
                values: Data::decode(&mut r)?,
            },
            T_PUSH_ROWS => Request::PushRows {
                id: r.u32()?,
                uid: r.u64()?,
                rows: r.slice_varint()?,
                values: Data::decode(&mut r)?,
            },
            T_FORGET => Request::Forget { uid: r.u64()? },
            T_DELETE_MATRIX => Request::DeleteMatrix { matrix: r.u32()? },
            T_REPL_POLL => Request::ReplPoll { from: r.u64()? },
            T_PROMOTE => Request::Promote,
            T_REPL_APPLY => Request::ReplApply {
                gen: r.u64()?,
                reset: r.u8()? != 0,
                tip: r.u64()?,
                records: decode_records(&mut r)?,
            },
            T_REPL_SEED => Request::ReplSeed {
                upstream: r.str()?,
                tip: r.u64()?,
                records: decode_records(&mut r)?,
            },
            T_DRAIN => Request::Drain,
            T_INFO => Request::ShardInfo,
            T_SHUTDOWN => Request::Shutdown,
            t => return Err(Error::Decode(format!("bad request tag {t}"))),
        };
        Ok(req)
    }
}

const R_OK: u8 = 1;
const R_UID: u8 = 2;
const R_ROWS: u8 = 3;
const R_PUSH_ACK: u8 = 4;
const R_INFO: u8 = 5;
const R_ERROR: u8 = 6;
const R_SPARSE_ROWS: u8 = 7;
const R_REPL_BATCH: u8 = 8;
const R_UNAVAILABLE: u8 = 9;
const R_DRAINED: u8 = 10;

impl Response {
    /// Serialize to wire bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            Response::Ok => w.u8(R_OK),
            Response::Uid(uid) => {
                w.u8(R_UID);
                w.u64(*uid);
            }
            Response::Rows(data) => {
                w.u8(R_ROWS);
                data.encode(&mut w);
            }
            Response::SparseRows(data) => {
                w.u8(R_SPARSE_ROWS);
                data.encode(&mut w);
            }
            Response::PushAck { fresh } => {
                w.u8(R_PUSH_ACK);
                w.u8(u8::from(*fresh));
            }
            Response::Info {
                shard_id,
                shards,
                scheme,
                matrices,
                local_rows,
                bytes,
                pending_uids,
                dedup_evictions,
                role,
                wal_records,
                wal_bytes,
                wal_commit_batches,
                repl_applied,
                repl_lag,
            } => {
                w.u8(R_INFO);
                w.u32(*shard_id);
                w.u32(*shards);
                w.u8(scheme.tag());
                w.u32(*matrices);
                w.u64(*local_rows);
                w.u64(*bytes);
                w.u64(*pending_uids);
                w.u64(*dedup_evictions);
                w.u8(*role);
                w.u64(*wal_records);
                w.u64(*wal_bytes);
                w.u64(*wal_commit_batches);
                w.u64(*repl_applied);
                w.u64(*repl_lag);
            }
            Response::ReplBatch { reset, next, tip, records } => {
                w.u8(R_REPL_BATCH);
                w.u8(u8::from(*reset));
                w.u64(*next);
                w.u64(*tip);
                encode_records(&mut w, records);
            }
            Response::Drained { tip } => {
                w.u8(R_DRAINED);
                w.u64(*tip);
            }
            Response::Unavailable(msg) => {
                w.u8(R_UNAVAILABLE);
                w.str(msg);
            }
            Response::Error(msg) => {
                w.u8(R_ERROR);
                w.str(msg);
            }
        }
        w.into_bytes()
    }

    /// Parse from wire bytes.
    pub fn decode(bytes: &[u8]) -> Result<Response> {
        let mut r = Reader::new(bytes);
        let resp = match r.u8()? {
            R_OK => Response::Ok,
            R_UID => Response::Uid(r.u64()?),
            R_ROWS => Response::Rows(Data::decode(&mut r)?),
            R_SPARSE_ROWS => Response::SparseRows(SparseData::decode(&mut r)?),
            R_PUSH_ACK => Response::PushAck { fresh: r.u8()? != 0 },
            R_INFO => Response::Info {
                shard_id: r.u32()?,
                shards: r.u32()?,
                scheme: {
                    let t = r.u8()?;
                    PartitionScheme::from_tag(t)
                        .ok_or_else(|| Error::Decode(format!("bad scheme tag {t}")))?
                },
                matrices: r.u32()?,
                local_rows: r.u64()?,
                bytes: r.u64()?,
                pending_uids: r.u64()?,
                dedup_evictions: r.u64()?,
                role: r.u8()?,
                wal_records: r.u64()?,
                wal_bytes: r.u64()?,
                wal_commit_batches: r.u64()?,
                repl_applied: r.u64()?,
                repl_lag: r.u64()?,
            },
            R_REPL_BATCH => Response::ReplBatch {
                reset: r.u8()? != 0,
                next: r.u64()?,
                tip: r.u64()?,
                records: decode_records(&mut r)?,
            },
            R_DRAINED => Response::Drained { tip: r.u64()? },
            R_UNAVAILABLE => Response::Unavailable(r.str()?),
            R_ERROR => Response::Error(r.str()?),
            t => return Err(Error::Decode(format!("bad response tag {t}"))),
        };
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::Pcg64;

    fn roundtrip_req(req: Request) {
        let bytes = req.encode();
        assert_eq!(Request::decode(&bytes).unwrap(), req);
    }

    fn roundtrip_resp(resp: Response) {
        let bytes = resp.encode();
        assert_eq!(Response::decode(&bytes).unwrap(), resp);
    }

    #[test]
    fn roundtrip_all_request_variants() {
        roundtrip_req(Request::CreateMatrix {
            id: 1,
            rows: 100,
            cols: 8,
            dtype: Dtype::I64,
            layout: Layout::Dense,
        });
        roundtrip_req(Request::CreateMatrix {
            id: 9,
            rows: 1 << 40,
            cols: 1000,
            dtype: Dtype::F32,
            layout: Layout::Sparse,
        });
        roundtrip_req(Request::PullRows { id: 2, rows: vec![0, 5, 99] });
        roundtrip_req(Request::PullSparseRows { id: 2, rows: vec![3, 1, 4, 1] });
        roundtrip_req(Request::PullTopK { id: 2, rows: vec![0, 7], k: 10 });
        roundtrip_req(Request::PullColSums { id: 2 });
        roundtrip_req(Request::GenUid);
        roundtrip_req(Request::PushCoords {
            id: 3,
            uid: 42,
            rows: vec![1, 2],
            cols: vec![3, 4],
            values: Data::I64(vec![1, -1]),
        });
        roundtrip_req(Request::PushRows {
            id: 4,
            uid: 43,
            rows: vec![7],
            values: Data::F32(vec![0.5, 1.5]),
        });
        roundtrip_req(Request::Forget { uid: 44 });
        roundtrip_req(Request::DeleteMatrix { matrix: 7 });
        roundtrip_req(Request::ReplPoll { from: 1 << 50 });
        roundtrip_req(Request::Promote);
        roundtrip_req(Request::ReplApply { gen: 0, reset: true, tip: 0, records: vec![] });
        roundtrip_req(Request::ReplApply {
            gen: 7,
            reset: false,
            tip: 1 << 40,
            records: vec![(1, vec![1, 2, 3]), (2, vec![]), (u64::MAX, vec![0; 64])],
        });
        roundtrip_req(Request::ReplSeed { upstream: String::new(), tip: 0, records: vec![] });
        roundtrip_req(Request::ReplSeed {
            upstream: "10.0.0.7:7071".into(),
            tip: 1 << 41,
            records: vec![(9, vec![4, 5]), (10, vec![])],
        });
        roundtrip_req(Request::Drain);
        roundtrip_req(Request::ShardInfo);
        roundtrip_req(Request::Shutdown);
    }

    #[test]
    fn roundtrip_all_response_variants() {
        roundtrip_resp(Response::Ok);
        roundtrip_resp(Response::Uid(99));
        roundtrip_resp(Response::Rows(Data::F32(vec![1.0, 2.0])));
        roundtrip_resp(Response::Rows(Data::I64(vec![-5, 5])));
        roundtrip_resp(Response::SparseRows(SparseData {
            lens: vec![2, 0, 1],
            cols: vec![1, 7, 3],
            values: Data::I64(vec![5, -2, 1]),
        }));
        roundtrip_resp(Response::SparseRows(SparseData {
            lens: vec![1],
            cols: vec![0],
            values: Data::F32(vec![0.5]),
        }));
        roundtrip_resp(Response::PushAck { fresh: true });
        roundtrip_resp(Response::PushAck { fresh: false });
        roundtrip_resp(Response::Info {
            shard_id: 3,
            shards: 8,
            scheme: PartitionScheme::Cyclic,
            matrices: 2,
            local_rows: 10,
            bytes: 160,
            pending_uids: 1,
            dedup_evictions: 4,
            role: 2,
            wal_records: 1 << 33,
            wal_bytes: 9999,
            wal_commit_batches: 17,
            repl_applied: 40,
            repl_lag: 3,
        });
        roundtrip_resp(Response::Info {
            shard_id: 0,
            shards: 1,
            scheme: PartitionScheme::Range,
            matrices: 0,
            local_rows: 0,
            bytes: 0,
            pending_uids: 0,
            dedup_evictions: 0,
            role: 0,
            wal_records: 0,
            wal_bytes: 0,
            wal_commit_batches: 0,
            repl_applied: 0,
            repl_lag: 0,
        });
        roundtrip_resp(Response::ReplBatch {
            reset: true,
            next: 51,
            tip: 60,
            records: vec![(50, vec![5; 8]), (50, vec![])],
        });
        roundtrip_resp(Response::ReplBatch {
            reset: false,
            next: 1,
            tip: 0,
            records: vec![],
        });
        roundtrip_resp(Response::Drained { tip: 0 });
        roundtrip_resp(Response::Drained { tip: 1 << 45 });
        roundtrip_resp(Response::Unavailable("backup".into()));
        roundtrip_resp(Response::Error("boom".into()));
    }

    #[test]
    fn inconsistent_sparse_payload_rejected() {
        // lens say 3 pairs but only 2 are present.
        let bad = Response::SparseRows(SparseData {
            lens: vec![3],
            cols: vec![1, 2],
            values: Data::I64(vec![1, 1]),
        });
        assert!(Response::decode(&bad.encode()).is_err());
    }

    #[test]
    fn sparse_rows_encoding_is_compact() {
        // A Zipf-tail pull: 1000 rows with 2 small-count pairs each must
        // cost a few bytes per pair, not a dense slab per row.
        let n_rows = 1000usize;
        let resp = Response::SparseRows(SparseData {
            lens: vec![2; n_rows],
            cols: (0..2 * n_rows).map(|i| (i % 100) as u32).collect(),
            values: Data::I64(vec![3; 2 * n_rows]),
        });
        let bytes = resp.encode().len();
        assert!(bytes < 8 * n_rows, "sparse pull of {n_rows} rows is {bytes} bytes");
    }

    #[test]
    fn roundtrip_random_sparse_rows() {
        forall(
            "sparse rows roundtrip",
            100,
            |rng: &mut Pcg64| {
                let n_rows = rng.below(40);
                let lens: Vec<u32> = (0..n_rows).map(|_| rng.below(6) as u32).collect();
                let pairs: usize = lens.iter().map(|&l| l as usize).sum();
                SparseData {
                    lens,
                    cols: (0..pairs).map(|_| rng.next_u32() >> 20).collect(),
                    values: Data::I64(
                        (0..pairs).map(|_| rng.below(100) as i64 - 50).collect(),
                    ),
                }
            },
            |data| {
                let resp = Response::SparseRows(data.clone());
                Response::decode(&resp.encode()).unwrap() == resp
            },
        );
    }

    #[test]
    fn garbage_rejected() {
        assert!(Request::decode(&[]).is_err());
        assert!(Request::decode(&[0xff]).is_err());
        assert!(Response::decode(&[0xee]).is_err());
    }

    #[test]
    fn roundtrip_random_push_coords() {
        forall(
            "push coords roundtrip",
            100,
            |rng: &mut Pcg64| {
                let n = rng.below(200);
                Request::PushCoords {
                    id: rng.next_u32(),
                    uid: rng.next_u64(),
                    rows: (0..n).map(|_| rng.next_u64() >> 16).collect(),
                    cols: (0..n).map(|_| rng.next_u32() >> 16).collect(),
                    values: Data::I64((0..n).map(|_| rng.next_u64() as i64).collect()),
                }
            },
            |req| Request::decode(&req.encode()).unwrap() == *req,
        );
    }

    #[test]
    fn push_message_size_is_compact() {
        // Paper §3.3: ~100k reassignments ≈ 2 MB. A reassignment is two
        // coordinate deltas (decrement old topic, increment new topic);
        // check that 100k deltas stay within the same order of magnitude.
        let n = 100_000;
        let req = Request::PushCoords {
            id: 1,
            uid: 1,
            rows: (0..n).map(|i| (i % 50_000) as u64).collect(),
            cols: (0..n).map(|i| (i % 1000) as u32).collect(),
            values: Data::I64(vec![1; n]),
        };
        let bytes = req.encode().len();
        assert!(bytes < 4 * 1024 * 1024, "100k-delta push is {bytes} bytes");
    }
}
