//! Parameter-server deployment configuration.

use std::path::PathBuf;
use std::time::Duration;

use crate::ps::partition::PartitionScheme;

/// Which transport carries client/shard traffic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportMode {
    /// In-process simulated network with fault injection (the default;
    /// single-process deployments and protocol tests).
    Sim,
    /// Real TCP over loopback: the server group binds one listener per
    /// shard on `127.0.0.1` (ephemeral ports) inside this process.
    TcpLoopback,
    /// Client-only: connect over TCP to externally running `serve`
    /// processes at these `host:port` addresses (one per shard).
    Connect(Vec<String>),
}

impl TransportMode {
    /// Parse a CLI transport name (`sim` | `tcp`). `Connect` is built
    /// from an explicit address list instead.
    pub fn parse(s: &str) -> Option<TransportMode> {
        match s {
            "sim" => Some(TransportMode::Sim),
            "tcp" => Some(TransportMode::TcpLoopback),
            _ => None,
        }
    }
}

/// Configuration shared by clients and the server group.
#[derive(Debug, Clone)]
pub struct PsConfig {
    /// Number of shard servers ("parameter servers" in the paper; 30 in
    /// their cluster).
    pub shards: usize,
    /// Row partitioning scheme (paper: cyclic).
    pub scheme: PartitionScheme,
    /// Transport carrying the pull/push traffic.
    pub transport: TransportMode,
    /// Base reply timeout before the first retry.
    pub timeout: Duration,
    /// Maximum attempts before a request is declared failed (paper §2.3:
    /// "after a specified number of retries ... we consider the pull
    /// operation failed").
    pub max_retries: u32,
    /// Multiplier applied to the timeout after each failed attempt
    /// (paper §2.3: exponential back-off).
    pub backoff_factor: f64,
    /// Upper bound on the per-attempt timeout.
    pub max_timeout: Duration,
    /// Bounded per-shard in-flight window for asynchronous operations:
    /// each shard gets this many client-side worker threads, and at most
    /// this many tickets (pulls, exactly-once push hand-shakes) may be
    /// outstanding against a shard at once — further submissions block
    /// (backpressure). `1` serializes per-shard traffic (the
    /// non-pipelined ablation); clamped to at least 1.
    pub pipeline_depth: usize,
    /// Bounded dedup window per shard: the maximum number of
    /// applied-but-not-forgotten push uids a shard remembers for
    /// exactly-once deduplication. When full, the oldest record is
    /// evicted (and counted in `ShardInfo::dedup_evictions`) — so a
    /// client that dies between its push ack and `Forget` no longer
    /// leaks an entry forever. `0` disables the bound.
    pub dedup_window: usize,
    /// Reader threads per shard in the server's op-dispatch executor:
    /// read ops (pulls, top-k, column sums, shard info) run concurrently
    /// on this many threads while pushes stay serialized on the shard's
    /// inbox thread. Clamped to at least 1.
    pub read_concurrency: usize,
    /// Durability: when set, each hosted shard keeps a write-ahead log
    /// under `<wal_dir>/shard-NNNN/` and replays it on start. `None`
    /// (the default) keeps the PR-6-and-earlier in-memory-only behavior.
    pub wal_dir: Option<PathBuf>,
    /// WAL: rotate the active log segment past this many bytes.
    pub wal_segment_bytes: u64,
    /// WAL: group-commit window — the longest a queued record waits
    /// before the committer fsyncs it anyway. Push acks do *not* wait
    /// for the fsync, so a crash can lose at most this window.
    pub wal_commit_window: Duration,
    /// WAL: sealed log segments that trigger folding the shard state
    /// into a snapshot segment (reclaiming deleted matrices' bytes).
    pub wal_compact_after: usize,
    /// Replication (client side): backup addresses, tier-major and
    /// parallel to a `Connect` transport's primaries — `k * shards`
    /// entries describe a chain of depth `k` (`backups[t*shards + s]`
    /// is shard `s`'s tier-`t+1` replica). Shard `s`'s failover route
    /// becomes `[primary, tier1, ..., tierk]` and the client walks it
    /// head-ward after repeated failures.
    pub backups: Vec<String>,
    /// Replication (server side): when set, every shard this server
    /// hosts runs as a *backup*, polling the corresponding upstream
    /// address (indexed by shard id) for committed WAL records and
    /// refusing data ops until promoted. In a chain every tier tails
    /// the current head; a `ReplSeed` re-points a replica at a new
    /// upstream mid-run.
    pub backup_of: Option<Vec<String>>,
    /// Consecutive per-shard failures before the client's courier
    /// advances to the next replica on the shard's failover route.
    pub failover_after: usize,
    /// Base pause before retrying a [`Response::Unavailable`] reply
    /// (an un-promoted or draining replica). The actual pause is
    /// jittered to `[pause/2, 3*pause/2)` so a fleet of clients does
    /// not re-stampede a promoting backup in lockstep.
    pub unavailable_pause: Duration,
    /// Seed for the retry-pause jitter stream. `0` (the default) mixes
    /// in per-process entropy; any other value makes the jitter
    /// sequence deterministic for replayable tests.
    pub retry_jitter_seed: u64,
}

impl Default for PsConfig {
    fn default() -> Self {
        PsConfig {
            shards: 4,
            scheme: PartitionScheme::Cyclic,
            transport: TransportMode::Sim,
            timeout: Duration::from_millis(100),
            max_retries: 12,
            backoff_factor: 2.0,
            max_timeout: Duration::from_secs(10),
            pipeline_depth: 4,
            dedup_window: 1 << 16,
            read_concurrency: 4,
            wal_dir: None,
            wal_segment_bytes: 1 << 20,
            wal_commit_window: Duration::from_millis(2),
            wal_compact_after: 4,
            backups: Vec::new(),
            backup_of: None,
            failover_after: 3,
            unavailable_pause: Duration::from_millis(100),
            retry_jitter_seed: 0,
        }
    }
}

impl PsConfig {
    /// Config for `shards` shards, defaults elsewhere.
    pub fn with_shards(shards: usize) -> PsConfig {
        PsConfig { shards, ..PsConfig::default() }
    }

    /// Config for a full deployment as the trainer, the cluster
    /// coordinator and cluster workers all build it: the client's
    /// per-shard in-flight window is the pull prefetch depth floored at
    /// 2 so push flushes still overlap sampling.
    pub fn deployment(
        shards: usize,
        scheme: PartitionScheme,
        transport: TransportMode,
        pipeline_depth: usize,
    ) -> PsConfig {
        PsConfig {
            shards,
            scheme,
            transport,
            pipeline_depth: pipeline_depth.max(2),
            ..PsConfig::default()
        }
    }

    /// Read-mostly client config for a serve-model replica attached to
    /// live shards: same deployment shape as the trainer's, but with an
    /// interactive failure budget — a dead shard should surface within a
    /// couple of seconds instead of riding out the training back-off
    /// schedule (~1 minute with the defaults).
    pub fn serving(
        shards: usize,
        scheme: PartitionScheme,
        transport: TransportMode,
    ) -> PsConfig {
        PsConfig {
            shards,
            scheme,
            transport,
            max_retries: 8,
            max_timeout: Duration::from_secs(2),
            ..PsConfig::default()
        }
    }

    /// Timeout for attempt `attempt` (0-based), growing exponentially and
    /// clamped to `max_timeout`.
    pub fn timeout_for_attempt(&self, attempt: u32) -> Duration {
        let scaled = self.timeout.as_secs_f64() * self.backoff_factor.powi(attempt as i32);
        Duration::from_secs_f64(scaled.min(self.max_timeout.as_secs_f64()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_exponentially() {
        let cfg = PsConfig::default();
        let t0 = cfg.timeout_for_attempt(0);
        let t1 = cfg.timeout_for_attempt(1);
        let t2 = cfg.timeout_for_attempt(2);
        assert_eq!(t1, t0 * 2);
        assert_eq!(t2, t0 * 4);
    }

    #[test]
    fn backoff_clamped() {
        let cfg = PsConfig::default();
        assert_eq!(cfg.timeout_for_attempt(30), cfg.max_timeout);
    }

    #[test]
    fn transport_mode_parses() {
        assert_eq!(TransportMode::parse("sim"), Some(TransportMode::Sim));
        assert_eq!(TransportMode::parse("tcp"), Some(TransportMode::TcpLoopback));
        assert_eq!(TransportMode::parse("carrier-pigeon"), None);
    }
}
