//! The serve-model inference tier: a serving front-end answering
//! topic-inference requests for unseen documents **directly off live
//! parameter-server shards**.
//!
//! Topology: clients ([`InferClient`]) speak the line protocol of
//! [`crate::net::infer`] to one or more serving replicas
//! ([`InferServer`]); each replica holds a read-mostly PS connection to
//! the shards, attaches the frozen word-topic table by its agreed id and
//! answers each request with a fixed-budget fold-in
//! ([`crate::lda::infer::InferEngine`]).
//!
//! A replica's serve loop is single-threaded on purpose: throughput
//! comes from **batching**, not thread fan-out. After the first request
//! of a batch arrives, the loop keeps draining its inbox for one
//! batching window so requests from concurrent clients coalesce — the
//! whole batch's distinct words are fetched in a *single* sparse pull,
//! and repeat documents are answered from the fold-in LRU without
//! touching the shards at all.

use std::net::SocketAddr;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::lda::infer::InferEngine;
use crate::net::infer::{InferRequest, InferResponse, ServeStats};
use crate::net::tcp::{resolve_addrs, TcpServer, TcpTransport};
use crate::net::{respond, Endpoint, Envelope, Inbox, Transport};
use crate::util::error::{Error, Result};

/// Default inbox-drain window for request coalescing.
pub const DEFAULT_BATCH_WINDOW: Duration = Duration::from_millis(2);

/// Reply timeout of one client round-trip (a batch may hold many
/// documents' fold-ins plus one model pull).
const INFER_TIMEOUT: Duration = Duration::from_secs(5);
/// Client attempts before giving up on a replica.
const INFER_RETRIES: u32 = 5;

/// One serving replica: a TCP listener plus the serve-loop thread that
/// owns the [`InferEngine`].
pub struct InferServer {
    addr: SocketAddr,
    server: TcpServer,
    handle: Option<JoinHandle<()>>,
}

impl InferServer {
    /// Bind `bind` (`host:port`; port 0 picks an ephemeral port) and
    /// start serving `engine`. The engine's shard connection stays alive
    /// for the life of the replica.
    pub fn start(
        engine: InferEngine,
        bind: &str,
        batch_window: Duration,
    ) -> Result<InferServer> {
        let addr = resolve_addrs(&[bind.to_string()])?[0];
        let (server, mut inboxes) = TcpServer::bind(&[addr])?;
        let inbox = inboxes.remove(0);
        let addr = server.addrs()[0];
        let handle = std::thread::Builder::new()
            .name("glint-serve-model".into())
            .spawn(move || serve_loop(&inbox, engine, batch_window))
            .map_err(Error::Io)?;
        Ok(InferServer { addr, server, handle: Some(handle) })
    }

    /// The address clients connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the serve loop exits (a client sent `Shutdown`), then
    /// stop accepting connections.
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.server.shutdown();
    }
}

/// What the serve loop needs from its engine: the real
/// [`InferEngine`], or a scripted stand-in in the model suite, which
/// drives the loop through a crafted [`Inbox`] to check the batching
/// window never loses or double-answers a request across shutdown.
pub trait BatchEngine {
    /// Answer one coalesced batch: one `(topic, count)` list per
    /// document, in batch order.
    fn infer_batch(&mut self, docs: &[&[u32]]) -> Result<Vec<Vec<(u32, u32)>>>;

    /// Cumulative counters for `Stats` answers; `requests` is the serve
    /// loop's own request count.
    fn serve_stats(&self, requests: u64) -> ServeStats;
}

impl BatchEngine for InferEngine {
    fn infer_batch(&mut self, docs: &[&[u32]]) -> Result<Vec<Vec<(u32, u32)>>> {
        InferEngine::infer_batch(self, docs)
    }

    fn serve_stats(&self, requests: u64) -> ServeStats {
        let s = self.stats();
        ServeStats {
            requests,
            docs: s.docs,
            cache_hits: s.cache_hits,
            words_pulled: s.words_pulled,
            sparse_pulls: s.sparse_pulls,
            batches: s.batches,
        }
    }
}

/// The replica's serve loop: block for the first request, drain the
/// inbox for one batching window, answer the coalesced batch, repeat.
///
/// Public for the model suite, which runs it against a scripted
/// [`BatchEngine`] over an [`Inbox::channel`] to explore batching /
/// shutdown interleavings; production replicas reach it through
/// [`InferServer::start`].
pub fn serve_loop<E: BatchEngine>(inbox: &Inbox, mut engine: E, window: Duration) {
    let mut requests = 0u64;
    loop {
        let Some(first) = inbox.recv() else {
            return; // listener gone
        };
        let mut batch: Vec<(Envelope, Vec<Vec<u32>>)> = Vec::new();
        let mut stop: Option<Envelope> = None;
        sort_envelope(first, &mut batch, &mut stop, &mut requests, &engine);
        // Coalescing window: requests arriving while the first is still
        // on the table join its batch and share one model pull.
        while stop.is_none() {
            match inbox.recv_timeout(window) {
                Some(env) => sort_envelope(env, &mut batch, &mut stop, &mut requests, &engine),
                None => break,
            }
        }
        if !batch.is_empty() {
            let docs: Vec<&[u32]> = batch
                .iter()
                .flat_map(|(_, docs)| docs.iter().map(|d| d.as_slice()))
                .collect();
            match engine.infer_batch(&docs) {
                Ok(mut results) => {
                    for (env, docs) in &batch {
                        let answered: Vec<Vec<(u32, u32)>> =
                            results.drain(..docs.len()).collect();
                        respond(env, InferResponse::Topics { docs: answered }.encode());
                    }
                }
                Err(e) => {
                    let msg = e.to_string();
                    for (env, _) in &batch {
                        respond(env, InferResponse::Error(msg.clone()).encode());
                    }
                }
            }
        }
        if let Some(env) = stop {
            respond(&env, InferResponse::Ok.encode());
            return;
        }
    }
}

/// Classify one envelope: inference work joins the batch; stats and
/// malformed requests are answered immediately; shutdown is deferred
/// until the in-flight batch has been answered.
fn sort_envelope<E: BatchEngine>(
    env: Envelope,
    batch: &mut Vec<(Envelope, Vec<Vec<u32>>)>,
    stop: &mut Option<Envelope>,
    requests: &mut u64,
    engine: &E,
) {
    match InferRequest::decode(&env.payload) {
        Ok(InferRequest::Infer { docs }) => {
            *requests += 1;
            batch.push((env, docs));
        }
        Ok(InferRequest::Stats) => {
            respond(&env, InferResponse::Stats(engine.serve_stats(*requests)).encode());
        }
        Ok(InferRequest::Shutdown) => *stop = Some(env),
        Err(e) => respond(&env, InferResponse::Error(e.to_string()).encode()),
    }
}

/// Line-protocol client of a serving replica. Cloning shares the
/// underlying multiplexed connection, so any number of threads can have
/// requests outstanding at once (and coalesce server-side).
#[derive(Clone)]
pub struct InferClient {
    ep: Endpoint,
}

impl InferClient {
    /// Connect to a serving replica at `addr` (`host:port`).
    pub fn connect(addr: &str) -> Result<InferClient> {
        let resolved = resolve_addrs(&[addr.to_string()])?;
        let transport = TcpTransport::connect(&resolved);
        Ok(InferClient { ep: transport.endpoint(0) })
    }

    /// One retrying round-trip. Retries are safe: inference is read-only
    /// against the frozen model, and a re-run of a lost reply hits the
    /// replica's fold-in cache.
    fn call(&self, req: &InferRequest) -> Result<InferResponse> {
        let payload = req.encode();
        for attempt in 0..INFER_RETRIES {
            match self.ep.request(payload.clone(), INFER_TIMEOUT) {
                Ok(bytes) => return InferResponse::decode(&bytes),
                Err(()) => {
                    std::thread::sleep(Duration::from_millis(50 << attempt.min(4)));
                }
            }
        }
        Err(Error::PsTimeout { op: "infer", shard: 0, attempts: INFER_RETRIES })
    }

    /// Infer topic counts for a batch of documents. Returns one
    /// `(topic, count)` list per document, in request order.
    pub fn infer(&self, docs: &[Vec<u32>]) -> Result<Vec<Vec<(u32, u32)>>> {
        match self.call(&InferRequest::Infer { docs: docs.to_vec() })? {
            InferResponse::Topics { docs: answered } => {
                if answered.len() != docs.len() {
                    return Err(Error::Decode(format!(
                        "serving replica answered {} of {} documents",
                        answered.len(),
                        docs.len()
                    )));
                }
                Ok(answered)
            }
            InferResponse::Error(m) => Err(Error::PsRejected(m)),
            other => Err(Error::Decode(format!("unexpected inference response {other:?}"))),
        }
    }

    /// Infer topic counts for a single document.
    pub fn infer_one(&self, tokens: &[u32]) -> Result<Vec<(u32, u32)>> {
        Ok(self.infer(&[tokens.to_vec()])?.pop().expect("one result per doc"))
    }

    /// The replica's cumulative serving counters.
    pub fn stats(&self) -> Result<ServeStats> {
        match self.call(&InferRequest::Stats)? {
            InferResponse::Stats(s) => Ok(s),
            InferResponse::Error(m) => Err(Error::PsRejected(m)),
            other => Err(Error::Decode(format!("unexpected stats response {other:?}"))),
        }
    }

    /// Ask the replica to exit its serve loop.
    pub fn shutdown(&self) -> Result<()> {
        match self.call(&InferRequest::Shutdown)? {
            InferResponse::Ok => Ok(()),
            InferResponse::Error(m) => Err(Error::PsRejected(m)),
            other => Err(Error::Decode(format!("unexpected shutdown response {other:?}"))),
        }
    }
}
