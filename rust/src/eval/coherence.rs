//! UMass topic coherence (Mimno et al., 2011) — an extension beyond the
//! paper's perplexity metric, useful for validating that low perplexity
//! corresponds to interpretable topics.
//!
//! `C(t) = Σ_{i<j} log( (D(w_i, w_j) + 1) / D(w_j) )` over the topic's
//! top words ordered by probability, where `D` counts documents
//! containing the word(s). Less negative = more coherent.

use std::collections::HashSet;

use crate::corpus::dataset::Corpus;
use crate::eval::perplexity::TopicModel;
use crate::eval::topics::top_words;

/// Document frequencies: for each word, the set of doc ids containing it
/// (built once, reused across topics).
pub struct DocFreq {
    postings: Vec<HashSet<u32>>,
}

impl DocFreq {
    /// Build from a corpus.
    pub fn build(corpus: &Corpus) -> DocFreq {
        let mut postings = vec![HashSet::new(); corpus.vocab_size as usize];
        for (d, doc) in corpus.docs.iter().enumerate() {
            for &w in &doc.tokens {
                postings[w as usize].insert(d as u32);
            }
        }
        DocFreq { postings }
    }

    /// Documents containing `w`.
    pub fn df(&self, w: u32) -> usize {
        self.postings[w as usize].len()
    }

    /// Documents containing both `a` and `b`.
    pub fn co_df(&self, a: u32, b: u32) -> usize {
        let (small, large) = if self.postings[a as usize].len() < self.postings[b as usize].len()
        {
            (&self.postings[a as usize], &self.postings[b as usize])
        } else {
            (&self.postings[b as usize], &self.postings[a as usize])
        };
        small.iter().filter(|d| large.contains(d)).count()
    }
}

/// UMass coherence of one topic over its `n` top words.
pub fn umass(model: &TopicModel, df: &DocFreq, topic: u32, n: usize) -> f64 {
    let top: Vec<u32> = top_words(model, topic, n).into_iter().map(|(w, _)| w).collect();
    let mut c = 0.0;
    for i in 1..top.len() {
        for j in 0..i {
            let d_j = df.df(top[j]);
            if d_j == 0 {
                continue;
            }
            let co = df.co_df(top[i], top[j]);
            c += ((co as f64 + 1.0) / d_j as f64).ln();
        }
    }
    c
}

/// Mean coherence over all topics.
pub fn mean_umass(model: &TopicModel, df: &DocFreq, n: usize) -> f64 {
    let total: f64 = (0..model.k).map(|k| umass(model, df, k, n)).sum();
    total / model.k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::dataset::Document;
    use crate::lda::hyper::LdaHyper;

    fn corpus() -> Corpus {
        Corpus {
            docs: vec![
                Document { tokens: vec![0, 1] },
                Document { tokens: vec![0, 1] },
                Document { tokens: vec![0, 1, 2] },
                Document { tokens: vec![2, 3] },
                Document { tokens: vec![3] },
            ],
            vocab_size: 4,
            vocab: vec![],
        }
    }

    #[test]
    fn df_counts() {
        let df = DocFreq::build(&corpus());
        assert_eq!(df.df(0), 3);
        assert_eq!(df.df(3), 2);
        assert_eq!(df.co_df(0, 1), 3);
        assert_eq!(df.co_df(0, 3), 0);
    }

    #[test]
    fn cooccurring_topic_more_coherent() {
        let df = DocFreq::build(&corpus());
        // Topic A: words 0,1 always co-occur. Topic B: words 0,3 never do.
        let model_a = TopicModel {
            k: 2,
            v: 4,
            // Topic 0 top words = {0,1}; topic 1 top words = {0,3}? build
            // counts accordingly.
            n_wk: vec![
                50, 40, // w0 in both
                50, 0, // w1 topic0
                0, 1, // w2
                0, 40, // w3 topic1
            ],
            n_k: vec![100, 81],
            hyper: LdaHyper { alpha: 0.5, beta: 0.01 },
        };
        let c0 = umass(&model_a, &df, 0, 2); // {0,1}
        let c1 = umass(&model_a, &df, 1, 2); // {0 or 3 ...}
        assert!(c0 > c1, "coherent {c0} vs incoherent {c1}");
    }

    #[test]
    fn mean_is_average() {
        let df = DocFreq::build(&corpus());
        let model = TopicModel {
            k: 1,
            v: 4,
            n_wk: vec![5, 4, 1, 1],
            n_k: vec![11],
            hyper: LdaHyper { alpha: 0.5, beta: 0.01 },
        };
        assert_eq!(mean_umass(&model, &df, 2), umass(&model, &df, 0, 2));
    }
}
