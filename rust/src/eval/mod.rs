//! Model evaluation: perplexity (the paper's quality metric throughout
//! Table 1 and Figure 6), topic inspection, and topic coherence.

pub mod coherence;
pub mod perplexity;
pub mod topics;
pub mod xla;

pub use perplexity::{holdout_perplexity, training_perplexity, TopicModel};
