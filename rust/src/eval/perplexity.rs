//! Perplexity evaluation.
//!
//! Perplexity of a corpus under a topic model is
//! `exp(-(Σ_d Σ_{w∈d} log p(w|d)) / N)` with
//! `p(w|d) = Σ_k θ_dk φ_kw`, the paper's quality metric (Table 1,
//! Fig. 6). Two modes:
//!
//! - [`training_perplexity`] — θ taken from the training doc-topic
//!   counts (what the paper's Figure 6 tracks during the ClueWeb run);
//! - [`holdout_perplexity`] — unseen documents are *folded in* by a few
//!   Gibbs passes with frozen φ to estimate θ, then scored.
//!
//! The inner loop — a documents×topics by topics×vocab product — is the
//! dense hot-spot the XLA/Pallas path accelerates
//! ([`crate::runtime::engine`]; kernel in `python/compile/kernels/`).

use crate::corpus::dataset::Corpus;
use crate::lda::gibbs::LocalModel;
use crate::lda::hyper::LdaHyper;
use crate::lda::sparse_counts::DocTopicCounts;
use crate::util::rng::Pcg64;

/// A trained topic model: the global count tables plus hyper-parameters.
/// This is what gets pulled off the parameter server at evaluation time.
#[derive(Debug, Clone)]
pub struct TopicModel {
    /// Topics.
    pub k: u32,
    /// Vocabulary size.
    pub v: u32,
    /// Word-topic counts, `v x k` row-major.
    pub n_wk: Vec<i64>,
    /// Topic totals.
    pub n_k: Vec<i64>,
    /// Hyper-parameters.
    pub hyper: LdaHyper,
}

impl TopicModel {
    /// Extract the global tables from a single-machine model.
    pub fn from_local(m: &LocalModel) -> TopicModel {
        TopicModel {
            k: m.k,
            v: m.v,
            n_wk: m.n_wk.clone(),
            n_k: m.n_k.clone(),
            hyper: m.hyper,
        }
    }

    /// φ_kw point estimate.
    #[inline]
    pub fn phi(&self, w: u32, k: u32) -> f64 {
        (self.n_wk[w as usize * self.k as usize + k as usize] as f64 + self.hyper.beta)
            / (self.n_k[k as usize] as f64 + self.v as f64 * self.hyper.beta)
    }

    /// Dense φ as f32 `k x v_block` for a word range (row-major by topic),
    /// the layout the XLA evaluation kernel consumes.
    pub fn phi_block_f32(&self, w_start: u32, w_end: u32) -> Vec<f32> {
        let kk = self.k as usize;
        let vb = (w_end - w_start) as usize;
        let mut out = vec![0f32; kk * vb];
        for k in 0..self.k {
            for (j, w) in (w_start..w_end).enumerate() {
                out[k as usize * vb + j] = self.phi(w, k) as f32;
            }
        }
        out
    }
}

/// θ estimate from sparse doc counts. The normalizer uses the counts'
/// own total (equals the document length whenever counts are consistent
/// with assignments), so θ always sums to exactly 1.
#[inline]
fn theta_of(counts: &DocTopicCounts, total: u64, k: u32, hyper: &LdaHyper, k_topics: u32) -> f64 {
    (counts.get(k) as f64 + hyper.alpha) / (total as f64 + k_topics as f64 * hyper.alpha)
}

/// Log-likelihood of a whole corpus given the model and per-document
/// topic counts; returns `(total_log_lik, token_count)`.
pub fn log_likelihood(
    model: &TopicModel,
    corpus: &Corpus,
    doc_counts: &[DocTopicCounts],
) -> (f64, u64) {
    log_likelihood_docs(model, &corpus.docs, doc_counts)
}

/// Log-likelihood of a document slice (e.g. one cluster partition's
/// docs) given the model and that slice's topic counts; returns
/// `(total_log_lik, token_count)`. Contributions are additive, so
/// partition results can be summed into the corpus total.
pub fn log_likelihood_docs(
    model: &TopicModel,
    docs: &[crate::corpus::dataset::Document],
    doc_counts: &[DocTopicCounts],
) -> (f64, u64) {
    assert_eq!(docs.len(), doc_counts.len());
    let mut total = 0.0;
    let mut tokens = 0u64;
    let kk = model.k;
    // Precompute per-topic normalizers.
    let vbeta = model.v as f64 * model.hyper.beta;
    let inv_nk: Vec<f64> =
        model.n_k.iter().map(|&n| 1.0 / (n as f64 + vbeta)).collect();
    let mut theta = vec![0.0f64; kk as usize];
    for (doc, counts) in docs.iter().zip(doc_counts) {
        let ctotal = counts.total();
        for k in 0..kk {
            theta[k as usize] = theta_of(counts, ctotal, k, &model.hyper, kk);
        }
        for &w in &doc.tokens {
            let row = &model.n_wk[w as usize * kk as usize..(w as usize + 1) * kk as usize];
            let mut p = 0.0;
            for k in 0..kk as usize {
                p += theta[k] * (row[k] as f64 + model.hyper.beta) * inv_nk[k];
            }
            total += p.max(1e-300).ln();
            tokens += 1;
        }
    }
    (total, tokens)
}

/// Perplexity from a log-likelihood total.
pub fn perplexity_from_loglik(total: f64, tokens: u64) -> f64 {
    if tokens == 0 {
        return f64::NAN;
    }
    (-total / tokens as f64).exp()
}

/// Perplexity from dense parameter estimates: `phi_vk` is `v x k`
/// row-major (by word), `thetas` one length-`k` distribution per
/// document. Used by the variational baselines, whose parameters are
/// real-valued rather than integer counts.
pub fn perplexity_dense(phi_vk: &[f64], thetas: &[Vec<f64>], k: u32, corpus: &Corpus) -> f64 {
    assert_eq!(thetas.len(), corpus.docs.len());
    let kk = k as usize;
    let mut total = 0.0;
    let mut tokens = 0u64;
    for (doc, theta) in corpus.docs.iter().zip(thetas) {
        for &w in &doc.tokens {
            let row = &phi_vk[w as usize * kk..(w as usize + 1) * kk];
            let p: f64 = row.iter().zip(theta).map(|(&f, &t)| f * t).sum();
            total += p.max(1e-300).ln();
            tokens += 1;
        }
    }
    perplexity_from_loglik(total, tokens)
}

/// Training-set perplexity of a single-machine model (θ from its own
/// doc-topic counts).
pub fn training_perplexity(model: &LocalModel, corpus: &Corpus) -> f64 {
    let tm = TopicModel::from_local(model);
    let (ll, n) = log_likelihood(&tm, corpus, &model.doc_counts);
    perplexity_from_loglik(ll, n)
}

/// Fold in an unseen document: `iters` Gibbs passes with frozen φ,
/// returning its doc-topic counts.
pub fn fold_in(
    model: &TopicModel,
    tokens: &[u32],
    iters: u32,
    rng: &mut Pcg64,
) -> DocTopicCounts {
    let kk = model.k as usize;
    let mut z: Vec<u32> = tokens.iter().map(|_| rng.below(kk) as u32).collect();
    let mut counts = DocTopicCounts::from_assignments(&z);
    let mut weights = vec![0.0f64; kk];
    let vbeta = model.v as f64 * model.hyper.beta;
    for _ in 0..iters {
        for (pos, &w) in tokens.iter().enumerate() {
            let old = z[pos];
            counts.decrement(old);
            let row = &model.n_wk[w as usize * kk..(w as usize + 1) * kk];
            for (k, wt) in weights.iter_mut().enumerate() {
                *wt = (counts.get(k as u32) as f64 + model.hyper.alpha)
                    * (row[k] as f64 + model.hyper.beta)
                    / (model.n_k[k] as f64 + vbeta);
            }
            let new = rng.categorical(&weights) as u32;
            counts.increment(new);
            z[pos] = new;
        }
    }
    counts
}

/// Held-out perplexity: fold in each document, then score it.
pub fn holdout_perplexity(
    model: &TopicModel,
    corpus: &Corpus,
    fold_in_iters: u32,
    seed: u64,
) -> f64 {
    let mut rng = Pcg64::new(seed);
    let counts: Vec<DocTopicCounts> = corpus
        .docs
        .iter()
        .map(|d| fold_in(model, &d.tokens, fold_in_iters, &mut rng))
        .collect();
    let (ll, n) = log_likelihood(model, corpus, &counts);
    perplexity_from_loglik(ll, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synth::{generate, SynthConfig};

    fn corpus() -> Corpus {
        generate(&SynthConfig {
            num_docs: 100,
            vocab_size: 200,
            num_topics: 4,
            avg_doc_len: 30.0,
            seed: 21,
            ..Default::default()
        })
    }

    #[test]
    fn uniform_model_perplexity_near_vocab_size() {
        // With zero counts, phi is uniform over V; theta irrelevant:
        // p(w|d) = 1/V so perplexity == V.
        let c = corpus();
        let m = TopicModel {
            k: 4,
            v: c.vocab_size,
            n_wk: vec![0; c.vocab_size as usize * 4],
            n_k: vec![0; 4],
            hyper: LdaHyper { alpha: 0.5, beta: 0.01 },
        };
        let counts: Vec<DocTopicCounts> =
            c.docs.iter().map(|_| DocTopicCounts::new()).collect();
        let (ll, n) = log_likelihood(&m, &c, &counts);
        let p = perplexity_from_loglik(ll, n);
        assert!(
            (p - c.vocab_size as f64).abs() < 1.0,
            "uniform perplexity {p} vs V {}",
            c.vocab_size
        );
    }

    #[test]
    fn perfect_model_beats_uniform() {
        // A model trained a bit must beat the uniform bound.
        let c = corpus();
        let mut m = crate::lda::gibbs::LocalModel::init_random(
            &c,
            4,
            LdaHyper::default_for(4),
            1,
        );
        let mut rng = Pcg64::new(2);
        for _ in 0..10 {
            crate::lda::gibbs::sweep(&mut m, &c, &mut rng);
        }
        let p = training_perplexity(&m, &c);
        assert!(p < c.vocab_size as f64 * 0.9, "{p}");
    }

    #[test]
    fn holdout_higher_than_training_but_finite() {
        let c = corpus();
        let (train, test) = c.split_holdout(5);
        let mut m = crate::lda::gibbs::LocalModel::init_random(
            &train,
            4,
            LdaHyper::default_for(4),
            3,
        );
        let mut rng = Pcg64::new(4);
        for _ in 0..10 {
            crate::lda::gibbs::sweep(&mut m, &train, &mut rng);
        }
        let tm = TopicModel::from_local(&m);
        let hp = holdout_perplexity(&tm, &test, 5, 5);
        assert!(hp.is_finite() && hp > 0.0);
        assert!(hp < test.vocab_size as f64 * 2.0);
    }

    #[test]
    fn phi_block_matches_scalar_phi() {
        let c = corpus();
        let m = crate::lda::gibbs::LocalModel::init_random(&c, 4, LdaHyper::default_for(4), 6);
        let tm = TopicModel::from_local(&m);
        let block = tm.phi_block_f32(10, 20);
        for k in 0..4u32 {
            for w in 10..20u32 {
                let want = tm.phi(w, k) as f32;
                let got = block[k as usize * 10 + (w - 10) as usize];
                assert!((want - got).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn empty_corpus_is_nan() {
        assert!(perplexity_from_loglik(0.0, 0).is_nan());
    }
}
