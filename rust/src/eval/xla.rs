//! XLA-accelerated perplexity: streams (doc batch, vocab block) tiles
//! through the AOT-compiled `perplexity` graph (whose hot-spot is the
//! Pallas doclik kernel — see `python/compile/`).
//!
//! Padding contract (matching `python/compile/model.py`):
//! - topics are padded to the compiled K and the graph receives the
//!   *real* K as a scalar; padded topic slots are masked out of θ
//!   **exactly** in-graph, so any model K ≤ compiled K evaluates
//!   bit-comparable to the pure-rust path;
//! - vocabulary blocks are padded with zero counts (contribute exactly 0);
//! - document batches are padded with empty docs (contribute exactly 0).

use crate::corpus::dataset::Corpus;
use crate::eval::perplexity::{perplexity_from_loglik, TopicModel};
use crate::lda::sparse_counts::DocTopicCounts;
use crate::runtime::artifacts::ArtifactSpec;
use crate::runtime::engine::{Engine, Input};
use crate::util::error::Result;

/// Total log-likelihood and token count of `corpus` under `model`,
/// computed on the XLA engine. `doc_counts` supplies θ (training-style
/// evaluation, same contract as [`crate::eval::perplexity::log_likelihood`]).
pub fn xla_log_likelihood(
    engine: &Engine,
    model: &TopicModel,
    corpus: &Corpus,
    doc_counts: &[DocTopicCounts],
) -> Result<(f64, u64)> {
    assert_eq!(corpus.docs.len(), doc_counts.len());
    let spec = engine.select("perplexity", model.k as usize)?;
    let d = spec.batch;
    let k_pad = spec.k;
    let vb = spec.vblock;
    let k = model.k as usize;

    // Precompute transposed, padded n_wk blocks and the padded n_k.
    let v = model.v as usize;
    let num_blocks = v.div_ceil(vb);
    let mut nwk_blocks: Vec<Vec<f32>> = Vec::with_capacity(num_blocks);
    for b in 0..num_blocks {
        let w0 = b * vb;
        let w1 = ((b + 1) * vb).min(v);
        let mut block = vec![0f32; k_pad * vb];
        for kk in 0..k {
            for w in w0..w1 {
                block[kk * vb + (w - w0)] = model.n_wk[w * k + kk] as f32;
            }
        }
        nwk_blocks.push(block);
    }
    let mut n_k = vec![0f32; k_pad];
    for kk in 0..k {
        n_k[kk] = model.n_k[kk] as f32;
    }

    let mut total = 0.0f64;
    let mut tokens = 0u64;
    let mut batch_counts: Vec<Vec<(usize, f32)>> = Vec::with_capacity(d);

    for batch_start in (0..corpus.docs.len()).step_by(d) {
        let batch_end = (batch_start + d).min(corpus.docs.len());
        let batch_len = batch_end - batch_start;
        // n_dk for the batch.
        let mut n_dk = vec![0f32; d * k_pad];
        for (i, counts) in doc_counts[batch_start..batch_end].iter().enumerate() {
            for (topic, c) in counts.iter() {
                n_dk[i * k_pad + topic as usize] = c as f32;
            }
        }
        // Sparse word counts per doc (once per batch).
        batch_counts.clear();
        for doc in &corpus.docs[batch_start..batch_end] {
            let mut ids: Vec<u32> = doc.tokens.clone();
            ids.sort_unstable();
            let mut pairs: Vec<(usize, f32)> = Vec::new();
            for &w in &ids {
                match pairs.last_mut() {
                    Some((lw, c)) if *lw == w as usize => *c += 1.0,
                    _ => pairs.push((w as usize, 1.0)),
                }
            }
            tokens += doc.tokens.len() as u64;
            batch_counts.push(pairs);
        }
        for (b, nwk_block) in nwk_blocks.iter().enumerate() {
            let w0 = b * vb;
            let w1 = ((b + 1) * vb).min(v);
            // Dense counts tile; skip empty tiles cheaply.
            let mut counts_tile = vec![0f32; d * vb];
            let mut any = false;
            for (i, pairs) in batch_counts.iter().enumerate() {
                // pairs are sorted by word id.
                let lo = pairs.partition_point(|&(w, _)| w < w0);
                for &(w, c) in &pairs[lo..] {
                    if w >= w1 {
                        break;
                    }
                    counts_tile[i * vb + (w - w0)] = c;
                    any = true;
                }
            }
            if !any {
                continue;
            }
            let out = run_tile(
                engine,
                &spec,
                &n_dk,
                nwk_block,
                &n_k,
                &counts_tile,
                model.hyper.alpha as f32,
                model.hyper.beta as f32,
                model.v as f32,
                k as f32,
                d,
                k_pad,
                vb,
            )?;
            for &ll in out.iter().take(batch_len) {
                total += ll as f64;
            }
        }
    }
    Ok((total, tokens))
}

#[allow(clippy::too_many_arguments)]
fn run_tile(
    engine: &Engine,
    spec: &ArtifactSpec,
    n_dk: &[f32],
    nwk_block: &[f32],
    n_k: &[f32],
    counts: &[f32],
    alpha: f32,
    beta: f32,
    vocab_size: f32,
    k_real: f32,
    d: usize,
    k: usize,
    vb: usize,
) -> Result<Vec<f32>> {
    let outs = engine.run_f32(
        spec,
        &[
            Input::F32(n_dk.to_vec(), vec![d, k]),
            Input::F32(nwk_block.to_vec(), vec![k, vb]),
            Input::F32(n_k.to_vec(), vec![k]),
            Input::F32(counts.to_vec(), vec![d, vb]),
            Input::F32(vec![alpha], vec![]),
            Input::F32(vec![beta], vec![]),
            Input::F32(vec![vocab_size], vec![]),
            Input::F32(vec![k_real], vec![]),
        ],
    )?;
    Ok(outs.into_iter().next().unwrap_or_default())
}

/// XLA-evaluated training perplexity.
pub fn xla_perplexity(
    engine: &Engine,
    model: &TopicModel,
    corpus: &Corpus,
    doc_counts: &[DocTopicCounts],
) -> Result<f64> {
    let (ll, tokens) = xla_log_likelihood(engine, model, corpus, doc_counts)?;
    Ok(perplexity_from_loglik(ll, tokens))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synth::{generate, SynthConfig};
    use crate::eval::perplexity::log_likelihood;
    use crate::lda::gibbs::LocalModel;
    use crate::lda::hyper::LdaHyper;

    fn engine_or_skip() -> Option<Engine> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        match Engine::new(&dir) {
            Ok(e) => Some(e),
            Err(_) => {
                eprintln!("skipping xla eval test: run `make artifacts`");
                None
            }
        }
    }

    #[test]
    fn xla_matches_rust_evaluator() {
        let Some(engine) = engine_or_skip() else { return };
        let c = generate(&SynthConfig {
            num_docs: 100,
            vocab_size: 3000, // > one vocab block to exercise blocking
            num_topics: 4,
            avg_doc_len: 40.0,
            seed: 71,
            ..Default::default()
        });
        // K = 128 matches the compiled artifact exactly: θ identical.
        let k = 128u32;
        let mut m = LocalModel::init_random(&c, k, LdaHyper::default_for(k as usize), 1);
        let mut rng = crate::util::rng::Pcg64::new(2);
        crate::lda::gibbs::sweep(&mut m, &c, &mut rng);
        let tm = crate::eval::perplexity::TopicModel::from_local(&m);
        let (rust_ll, rust_tok) = log_likelihood(&tm, &c, &m.doc_counts);
        let (xla_ll, xla_tok) = xla_log_likelihood(&engine, &tm, &c, &m.doc_counts).unwrap();
        assert_eq!(rust_tok, xla_tok);
        let rel = ((rust_ll - xla_ll) / rust_ll).abs();
        assert!(rel < 1e-4, "rust {rust_ll} vs xla {xla_ll} (rel {rel:.2e})");
    }

    #[test]
    fn xla_padded_k_close_to_rust() {
        let Some(engine) = engine_or_skip() else { return };
        let c = generate(&SynthConfig {
            num_docs: 60,
            vocab_size: 500,
            num_topics: 4,
            avg_doc_len: 30.0,
            seed: 72,
            ..Default::default()
        });
        // K = 20 padded to 128: the in-graph mask makes this exact.
        let k = 20u32;
        let mut m = LocalModel::init_random(&c, k, LdaHyper::default_for(k as usize), 3);
        let mut rng = crate::util::rng::Pcg64::new(4);
        for _ in 0..3 {
            crate::lda::gibbs::sweep(&mut m, &c, &mut rng);
        }
        let tm = crate::eval::perplexity::TopicModel::from_local(&m);
        let (rust_ll, n) = log_likelihood(&tm, &c, &m.doc_counts);
        let rust_p = perplexity_from_loglik(rust_ll, n);
        let xla_p = xla_perplexity(&engine, &tm, &c, &m.doc_counts).unwrap();
        let rel = ((rust_p - xla_p) / rust_p).abs();
        assert!(rel < 1e-4, "rust {rust_p} vs xla {xla_p} (rel {rel:.2e})");
    }
}
