//! Topic inspection: top words per topic (the paper's qualitative
//! evaluation — "uncovering some of the prevalent themes that appear on
//! the Web").

use crate::eval::perplexity::TopicModel;
use crate::util::topk::TopK;

/// The `n` highest-probability word ids of a topic, with φ values,
/// descending.
pub fn top_words(model: &TopicModel, topic: u32, n: usize) -> Vec<(u32, f64)> {
    let mut tk = TopK::new(n);
    for w in 0..model.v {
        tk.push(model.phi(w, topic), w);
    }
    tk.into_sorted().into_iter().map(|(p, w)| (w, p)).collect()
}

/// Render a topic as a string of its top words (uses the corpus
/// vocabulary when available, else `w<id>`).
pub fn describe_topic(model: &TopicModel, vocab: &[String], topic: u32, n: usize) -> String {
    top_words(model, topic, n)
        .into_iter()
        .map(|(w, _)| {
            vocab
                .get(w as usize)
                .cloned()
                .unwrap_or_else(|| format!("w{w}"))
        })
        .collect::<Vec<_>>()
        .join(" ")
}

/// Summarize all topics, largest first (by `n_k` mass).
pub fn summarize(model: &TopicModel, vocab: &[String], words_per_topic: usize) -> Vec<String> {
    let mut order: Vec<u32> = (0..model.k).collect();
    order.sort_by_key(|&k| std::cmp::Reverse(model.n_k[k as usize]));
    order
        .into_iter()
        .map(|k| {
            format!(
                "topic {k:>4} ({} tokens): {}",
                model.n_k[k as usize],
                describe_topic(model, vocab, k, words_per_topic)
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lda::hyper::LdaHyper;

    fn toy_model() -> TopicModel {
        // 2 topics, 4 words. Topic 0 loves words 0,1; topic 1 loves 2,3.
        TopicModel {
            k: 2,
            v: 4,
            n_wk: vec![
                90, 1, // w0
                80, 2, // w1
                3, 70, // w2
                2, 60, // w3
            ],
            n_k: vec![175, 133],
            hyper: LdaHyper { alpha: 0.5, beta: 0.01 },
        }
    }

    #[test]
    fn top_words_ranked() {
        let m = toy_model();
        let top = top_words(&m, 0, 2);
        assert_eq!(top[0].0, 0);
        assert_eq!(top[1].0, 1);
        assert!(top[0].1 > top[1].1);
        let top = top_words(&m, 1, 2);
        assert_eq!(top[0].0, 2);
    }

    #[test]
    fn describe_uses_vocab() {
        let m = toy_model();
        let vocab: Vec<String> =
            ["gold", "ring", "recipe", "meat"].iter().map(|s| s.to_string()).collect();
        let s = describe_topic(&m, &vocab, 0, 2);
        assert_eq!(s, "gold ring");
        let s = describe_topic(&m, &[], 1, 1);
        assert_eq!(s, "w2");
    }

    #[test]
    fn summarize_orders_by_mass() {
        let m = toy_model();
        let lines = summarize(&m, &[], 2);
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("topic    0"), "{}", lines[0]);
    }
}
