//! Variational EM LDA — the `spark.mllib` `EMLDAOptimizer` algorithm
//! (Asuncion et al., 2009: "smoothed" EM on expected counts).
//!
//! Per iteration, for every token of every document the responsibility
//!
//! `γ_dwk ∝ (N_dk + α)(N_wk + β) / (N_k + Vβ)`
//!
//! is computed from the *previous* iteration's expected counts, and new
//! expected counts are accumulated from the γs. This is O(K) work per
//! token and — in the GraphX execution — reshuffles the rebuilt count
//! tables every iteration ([`crate::baselines::shuffle`]).
//!
//! The E-step over documents is embarrassingly parallel; we use the same
//! worker count as the LightLDA trainer so runtimes are comparable. The
//! dense per-document E-step inner product is exactly the computation the
//! AOT-compiled XLA graph `em_estep` performs; the rust fallback here is
//! used when artifacts are absent (and as the correctness oracle for it).

use crate::baselines::shuffle;
use crate::corpus::dataset::Corpus;
use crate::eval::perplexity::perplexity_dense;
use crate::lda::hyper::LdaHyper;
use crate::metrics::{Report, Row};
use crate::util::error::{Error, Result};
use crate::util::rng::Pcg64;
use crate::util::threadpool::parallel_chunks;
use crate::util::timer::Stopwatch;

/// EM configuration.
#[derive(Debug, Clone)]
pub struct EmConfig {
    /// Topics.
    pub num_topics: u32,
    /// EM iterations.
    pub iterations: u32,
    /// Doc-topic concentration; `<= 0` → MLlib default `50/K + 1`.
    pub alpha: f64,
    /// Topic-word concentration; `<= 0` → MLlib default `1.1`.
    pub beta: f64,
    /// Worker threads.
    pub workers: usize,
    /// Seed for the random initialization.
    pub seed: u64,
    /// Evaluate training perplexity every N iterations (0 = never).
    pub eval_every: u32,
    /// Materialize the per-iteration shuffle to disk (serialize the
    /// rebuilt tables and read them back), as Spark's GraphX execution
    /// does. `None` disables the I/O (pure-compute ablation) while the
    /// accounting model still reports the bytes.
    pub shuffle_dir: Option<std::path::PathBuf>,
}

impl Default for EmConfig {
    fn default() -> Self {
        EmConfig {
            num_topics: 20,
            iterations: 30,
            alpha: 0.0,
            beta: 0.0,
            workers: 4,
            seed: 0xe111,
            eval_every: 0,
            shuffle_dir: Some(std::env::temp_dir().join("glint_em_shuffle")),
        }
    }
}

impl EmConfig {
    fn resolved(&self) -> (f64, f64) {
        let alpha = if self.alpha > 0.0 { self.alpha } else { 50.0 / self.num_topics as f64 + 1.0 };
        let beta = if self.beta > 0.0 { self.beta } else { 1.1 };
        (alpha, beta)
    }
}

/// Trained EM model: expected count tables (dense f64).
#[derive(Debug, Clone)]
pub struct EmModel {
    /// Topics.
    pub k: u32,
    /// Vocabulary size.
    pub v: u32,
    /// Expected word-topic counts, `v x k` row-major.
    pub n_wk: Vec<f64>,
    /// Expected topic totals.
    pub n_k: Vec<f64>,
    /// Expected doc-topic counts per document.
    pub n_dk: Vec<Vec<f64>>,
    /// Effective hyper-parameters.
    pub hyper: LdaHyper,
    /// Cumulative simulated shuffle-write bytes.
    pub shuffle_bytes: u64,
    /// Per-iteration report.
    pub report: Report,
}

impl EmModel {
    /// φ point estimate as a dense `v x k` matrix.
    pub fn phi_vk(&self) -> Vec<f64> {
        let kk = self.k as usize;
        let vbeta = self.v as f64 * self.hyper.beta;
        let mut phi = vec![0.0; self.v as usize * kk];
        for w in 0..self.v as usize {
            for k in 0..kk {
                phi[w * kk + k] =
                    (self.n_wk[w * kk + k] + self.hyper.beta) / (self.n_k[k] + vbeta);
            }
        }
        phi
    }

    /// θ estimates per document.
    pub fn thetas(&self) -> Vec<Vec<f64>> {
        let kk = self.k as usize;
        self.n_dk
            .iter()
            .map(|row| {
                let total: f64 = row.iter().sum::<f64>() + kk as f64 * self.hyper.alpha;
                row.iter().map(|&c| (c + self.hyper.alpha) / total).collect()
            })
            .collect()
    }

    /// Training perplexity.
    pub fn perplexity(&self, corpus: &Corpus) -> f64 {
        perplexity_dense(&self.phi_vk(), &self.thetas(), self.k, corpus)
    }
}

type WorkerStats = (Vec<f64>, Vec<f64>, Vec<(usize, Vec<f64>)>);

/// Serialize each worker's shuffle payload to disk and read it back —
/// the I/O Spark's EM pays every iteration.
fn spill_and_reload(
    dir: &std::path::Path,
    seed: u64,
    iter: u32,
    results: Vec<WorkerStats>,
) -> Result<Vec<WorkerStats>> {
    use crate::util::codec::{Reader, Writer};
    std::fs::create_dir_all(dir)?;
    let mut reloaded = Vec::with_capacity(results.len());
    for (widx, (loc_wk, loc_k, loc_dk)) in results.into_iter().enumerate() {
        let mut w = Writer::with_capacity(8 * (loc_wk.len() + loc_k.len()) + 64);
        w.usize(loc_wk.len());
        for &x in &loc_wk {
            w.f64(x);
        }
        w.usize(loc_k.len());
        for &x in &loc_k {
            w.f64(x);
        }
        w.usize(loc_dk.len());
        for (d, dk) in &loc_dk {
            w.usize(*d);
            w.usize(dk.len());
            for &x in dk {
                w.f64(x);
            }
        }
        let path = dir.join(format!("shuffle-{seed:x}-{iter}-{widx}.bin"));
        std::fs::write(&path, w.into_bytes())?;
        let bytes = std::fs::read(&path)?;
        let _ = std::fs::remove_file(&path);
        let mut r = Reader::new(&bytes);
        let n = r.usize()?;
        let mut wk = Vec::with_capacity(n);
        for _ in 0..n {
            wk.push(r.f64()?);
        }
        let n = r.usize()?;
        let mut kv = Vec::with_capacity(n);
        for _ in 0..n {
            kv.push(r.f64()?);
        }
        let n = r.usize()?;
        let mut dks = Vec::with_capacity(n);
        for _ in 0..n {
            let d = r.usize()?;
            let m = r.usize()?;
            let mut dk = Vec::with_capacity(m);
            for _ in 0..m {
                dk.push(r.f64()?);
            }
            dks.push((d, dk));
        }
        reloaded.push((wk, kv, dks));
    }
    Ok(reloaded)
}

/// Run variational EM. Returns the trained model with its report.
pub fn train(cfg: &EmConfig, corpus: &Corpus) -> Result<EmModel> {
    if corpus.num_docs() == 0 {
        return Err(Error::Config("empty corpus".into()));
    }
    let (alpha, beta) = cfg.resolved();
    let k = cfg.num_topics;
    let kk = k as usize;
    let v = corpus.vocab_size;
    let mut rng = Pcg64::new(cfg.seed);

    // Random soft initialization: every token spreads a unit of mass over
    // a random distribution (equivalent to MLlib's random vertex init).
    let mut n_wk = vec![0.0f64; v as usize * kk];
    let mut n_k = vec![0.0f64; kk];
    let mut n_dk: Vec<Vec<f64>> = Vec::with_capacity(corpus.num_docs());
    let mut g = Vec::new();
    for doc in &corpus.docs {
        let mut dk = vec![0.0; kk];
        for &w in &doc.tokens {
            rng.dirichlet_sym(1.0, kk, &mut g);
            for (kidx, &gi) in g.iter().enumerate() {
                n_wk[w as usize * kk + kidx] += gi;
                n_k[kidx] += gi;
                dk[kidx] += gi;
            }
        }
        n_dk.push(dk);
    }

    let edges = shuffle::distinct_edges(corpus);
    let report = Report::new();
    let mut shuffle_bytes = 0u64;
    let doc_ids: Vec<usize> = (0..corpus.num_docs()).collect();

    for iter in 0..cfg.iterations {
        let sw = Stopwatch::new();
        let vbeta = v as f64 * beta;
        // E-step: compute responsibilities from the frozen previous
        // tables; accumulate fresh tables. Parallel over doc chunks.
        let results: Vec<WorkerStats> = parallel_chunks(
            &doc_ids,
            cfg.workers,
            |_, chunk| {
                let mut loc_wk = vec![0.0f64; v as usize * kk];
                let mut loc_k = vec![0.0f64; kk];
                let mut loc_dk = Vec::with_capacity(chunk.len());
                let mut gamma = vec![0.0f64; kk];
                for &d in chunk {
                    let doc = &corpus.docs[d];
                    let prev_dk = &n_dk[d];
                    let mut new_dk = vec![0.0f64; kk];
                    for &w in &doc.tokens {
                        let row = &n_wk[w as usize * kk..(w as usize + 1) * kk];
                        let mut total = 0.0;
                        for kidx in 0..kk {
                            let val = (prev_dk[kidx] + alpha - 1.0).max(1e-10)
                                * (row[kidx] + beta - 1.0).max(1e-10)
                                / (n_k[kidx] + vbeta - v as f64).max(1e-10);
                            gamma[kidx] = val;
                            total += val;
                        }
                        let inv = 1.0 / total;
                        for kidx in 0..kk {
                            let gnorm = gamma[kidx] * inv;
                            loc_wk[w as usize * kk + kidx] += gnorm;
                            loc_k[kidx] += gnorm;
                            new_dk[kidx] += gnorm;
                        }
                    }
                    loc_dk.push((d, new_dk));
                }
                (loc_wk, loc_k, loc_dk)
            },
        );
        // M-step "shuffle": rebuild the global tables. With a shuffle
        // dir configured, the per-worker tables take the same round trip
        // Spark's execution gives them — serialized to shuffle files on
        // disk, then read back and merged — so the measured runtime pays
        // for the bytes the accounting model reports.
        let results = if let Some(dir) = &cfg.shuffle_dir {
            spill_and_reload(dir, cfg.seed, iter, results)?
        } else {
            results
        };
        n_wk.iter_mut().for_each(|x| *x = 0.0);
        n_k.iter_mut().for_each(|x| *x = 0.0);
        for (loc_wk, loc_k, loc_dk) in results {
            for (dst, src) in n_wk.iter_mut().zip(&loc_wk) {
                *dst += src;
            }
            for (dst, src) in n_k.iter_mut().zip(&loc_k) {
                *dst += src;
            }
            for (d, dk) in loc_dk {
                n_dk[d] = dk;
            }
        }
        shuffle_bytes += shuffle::em_shuffle_bytes_per_iter(corpus, k, edges);
        let mut row = Row::new().set("iter", iter as f64 + 1.0).set("seconds", sw.secs());
        if cfg.eval_every > 0 && (iter + 1) % cfg.eval_every == 0 {
            let m = EmModel {
                k,
                v,
                n_wk: n_wk.clone(),
                n_k: n_k.clone(),
                n_dk: n_dk.clone(),
                hyper: LdaHyper { alpha, beta },
                shuffle_bytes,
                report: Report::new(),
            };
            row = row.set("perplexity", m.perplexity(corpus));
        }
        report.push(row);
    }

    Ok(EmModel {
        k,
        v,
        n_wk,
        n_k,
        n_dk,
        hyper: LdaHyper { alpha, beta },
        shuffle_bytes,
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synth::{generate, SynthConfig};

    fn corpus() -> Corpus {
        generate(&SynthConfig {
            num_docs: 120,
            vocab_size: 250,
            num_topics: 4,
            avg_doc_len: 30.0,
            seed: 44,
            ..Default::default()
        })
    }

    fn cfg() -> EmConfig {
        EmConfig { num_topics: 6, iterations: 8, workers: 3, ..Default::default() }
    }

    #[test]
    fn mass_conserved() {
        let c = corpus();
        let m = train(&cfg(), &c).unwrap();
        let total_tokens = c.num_tokens() as f64;
        let wk_total: f64 = m.n_wk.iter().sum();
        let k_total: f64 = m.n_k.iter().sum();
        assert!((wk_total - total_tokens).abs() < 1e-6 * total_tokens, "{wk_total}");
        assert!((k_total - total_tokens).abs() < 1e-6 * total_tokens);
        for (d, dk) in m.n_dk.iter().enumerate() {
            let s: f64 = dk.iter().sum();
            assert!(
                (s - c.docs[d].len() as f64).abs() < 1e-6 * (1.0 + s),
                "doc {d}: {s} vs {}",
                c.docs[d].len()
            );
        }
    }

    #[test]
    fn em_reduces_perplexity() {
        // MLlib-default priors (alpha = 50/K + 1) smooth heavily, so use
        // mild explicit priors to expose the EM improvement direction.
        let c = corpus();
        let mut config = cfg();
        config.alpha = 1.3;
        config.beta = 1.05;
        config.iterations = 1;
        let early = train(&config, &c).unwrap().perplexity(&c);
        config.iterations = 15;
        let late = train(&config, &c).unwrap().perplexity(&c);
        assert!(late < early * 0.98, "{early} -> {late}");
        assert!(late < c.vocab_size as f64 / 2.0, "far better than uniform");
    }

    #[test]
    fn shuffle_bytes_accumulate() {
        let c = corpus();
        let m = train(&cfg(), &c).unwrap();
        let per = shuffle::em_shuffle_bytes_per_iter(&c, 6, shuffle::distinct_edges(&c));
        assert_eq!(m.shuffle_bytes, per * 8);
    }

    #[test]
    fn deterministic() {
        let c = corpus();
        let a = train(&cfg(), &c).unwrap();
        let b = train(&cfg(), &c).unwrap();
        assert_eq!(a.n_k, b.n_k);
    }

    #[test]
    fn phi_rows_normalize() {
        let c = corpus();
        let m = train(&cfg(), &c).unwrap();
        let phi = m.phi_vk();
        for k in 0..6usize {
            let s: f64 = (0..m.v as usize).map(|w| phi[w * 6 + k]).sum();
            assert!((s - 1.0).abs() < 1e-6, "topic {k} sums to {s}");
        }
    }
}
