//! Comparison baselines: the two LDA implementations Spark MLlib ships,
//! re-implemented from their source algorithms (paper §4 compares against
//! both on ClueWeb12 B13 subsets, Table 1).
//!
//! - [`em`] — the **variational EM** algorithm (Asuncion et al., UAI'09),
//!   MLlib's `EMLDAOptimizer`. Each iteration recomputes soft topic
//!   responsibilities for every token from the previous iteration's
//!   expected counts and rebuilds the count tables — O(K) per token, and
//!   in Spark the rebuilt `V x K` + `D x K` tables are *shuffled* across
//!   the cluster each iteration (the paper's shuffle-write column).
//! - [`online`] — **Online variational Bayes** (Hoffman et al.,
//!   NIPS'10), MLlib's `OnlineLDAOptimizer`: minibatch stochastic updates
//!   of the topic-word variational parameter λ. No shuffle (driver-side
//!   aggregation), but O(K) per token with digamma-heavy inner loops.
//! - [`shuffle`] — the shuffle-write accounting model that maps our
//!   in-process execution onto the bytes Spark would move.

pub mod em;
pub mod online;
pub mod shuffle;
