//! Online variational Bayes LDA (Hoffman, Blei & Bach, NIPS 2010) — the
//! `spark.mllib` `OnlineLDAOptimizer` algorithm.
//!
//! The topic-word variational parameter `λ` (V×K) is updated from
//! minibatches: for each minibatch the per-document variational
//! distribution `γ_d` is fit by coordinate ascent (digamma-based
//! multiplicative updates), sufficient statistics are aggregated, and
//! `λ ← (1-ρ_t) λ + ρ_t λ̂` with learning rate `ρ_t = (τ₀ + t)^{-κ}`.
//!
//! O(K) per token with transcendental functions in the inner loop — the
//! paper's Table 1 shows its runtime exploding with K (21.5 min at K=20
//! vs 233.2 min at K=80 on 10% of ClueWeb12 B13), which this
//! implementation reproduces in shape. No shuffle write: sufficient
//! statistics are aggregated driver-side.

use crate::corpus::dataset::Corpus;
use crate::eval::perplexity::perplexity_dense;
use crate::metrics::{Report, Row};
use crate::util::error::{Error, Result};
use crate::util::math::digamma;
use crate::util::rng::Pcg64;
use crate::util::threadpool::parallel_chunks;
use crate::util::timer::Stopwatch;

/// Online VB configuration (MLlib defaults).
#[derive(Debug, Clone)]
pub struct OnlineConfig {
    /// Topics.
    pub num_topics: u32,
    /// Passes over the corpus.
    pub epochs: u32,
    /// Minibatch size in documents (MLlib default: 5% of corpus; we use
    /// an absolute count).
    pub batch_size: usize,
    /// Doc-topic concentration; `<= 0` → `1/K`.
    pub alpha: f64,
    /// Topic-word concentration; `<= 0` → `1/K`.
    pub eta: f64,
    /// Learning-rate offset τ₀.
    pub tau0: f64,
    /// Learning-rate decay κ.
    pub kappa: f64,
    /// Max coordinate-ascent iterations per document.
    pub inner_iters: u32,
    /// Convergence threshold on mean |Δγ|.
    pub gamma_tol: f64,
    /// Worker threads for the minibatch E-step.
    pub workers: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        OnlineConfig {
            num_topics: 20,
            epochs: 2,
            batch_size: 256,
            alpha: 0.0,
            eta: 0.0,
            tau0: 1024.0,
            kappa: 0.51,
            inner_iters: 25,
            gamma_tol: 0.0,
            workers: 4,
            seed: 0x071e,
        }
    }
}

/// Trained online-VB model.
#[derive(Debug, Clone)]
pub struct OnlineModel {
    /// Topics.
    pub k: u32,
    /// Vocabulary size.
    pub v: u32,
    /// Variational topic-word parameter, `v x k` row-major.
    pub lambda: Vec<f64>,
    /// Effective α.
    pub alpha: f64,
    /// Effective η.
    pub eta: f64,
    /// Per-iteration report.
    pub report: Report,
}

impl OnlineModel {
    /// φ point estimates (`E[β] = λ / Σ_w λ`), `v x k` row-major.
    pub fn phi_vk(&self) -> Vec<f64> {
        let kk = self.k as usize;
        let mut col_sums = vec![0.0f64; kk];
        for w in 0..self.v as usize {
            for k in 0..kk {
                col_sums[k] += self.lambda[w * kk + k];
            }
        }
        let mut phi = vec![0.0; self.lambda.len()];
        for w in 0..self.v as usize {
            for k in 0..kk {
                phi[w * kk + k] = self.lambda[w * kk + k] / col_sums[k];
            }
        }
        phi
    }

    /// Fit θ for given documents (one E-step with frozen λ) and return
    /// training perplexity.
    pub fn perplexity(&self, corpus: &Corpus, workers: usize) -> f64 {
        let elog_beta = expect_log_beta(&self.lambda, self.v, self.k);
        let doc_ids: Vec<usize> = (0..corpus.num_docs()).collect();
        let thetas: Vec<Vec<f64>> = parallel_chunks(&doc_ids, workers, |_, chunk| {
            chunk
                .iter()
                .map(|&d| {
                    // Fixed passes (matching training) so evaluation
                    // cost is deterministic and O(K).
                    let gamma = fit_gamma(
                        &corpus.docs[d].tokens,
                        &elog_beta,
                        self.k,
                        self.alpha,
                        25,
                        0.0,
                    );
                    let total: f64 = gamma.iter().sum();
                    gamma.iter().map(|&g| g / total).collect()
                })
                .collect::<Vec<Vec<f64>>>()
        })
        .into_iter()
        .flatten()
        .collect();
        perplexity_dense(&self.phi_vk(), &thetas, self.k, corpus)
    }
}

/// `E[log β_kw]` = ψ(λ_wk) − ψ(Σ_w λ_wk), laid out `v x k`.
fn expect_log_beta(lambda: &[f64], v: u32, k: u32) -> Vec<f64> {
    let kk = k as usize;
    let mut col_sums = vec![0.0f64; kk];
    for w in 0..v as usize {
        for kidx in 0..kk {
            col_sums[kidx] += lambda[w * kk + kidx];
        }
    }
    let psi_sums: Vec<f64> = col_sums.iter().map(|&s| digamma(s)).collect();
    let mut out = vec![0.0; lambda.len()];
    for w in 0..v as usize {
        for kidx in 0..kk {
            out[w * kk + kidx] = digamma(lambda[w * kk + kidx]) - psi_sums[kidx];
        }
    }
    out
}

/// Coordinate-ascent fit of one document's γ given frozen `E[log β]`.
fn fit_gamma(
    tokens: &[u32],
    elog_beta: &[f64],
    k: u32,
    alpha: f64,
    max_iters: u32,
    tol: f64,
) -> Vec<f64> {
    let kk = k as usize;
    // Unique words + counts.
    let mut ids: Vec<u32> = tokens.to_vec();
    ids.sort_unstable();
    let mut words: Vec<(u32, f64)> = Vec::new();
    for &w in &ids {
        match words.last_mut() {
            Some((lw, c)) if *lw == w => *c += 1.0,
            _ => words.push((w, 1.0)),
        }
    }
    let mut gamma = vec![1.0f64; kk];
    let mut exp_elog_theta = vec![0.0f64; kk];
    // phi_norm_w = sum_k expElogTheta_k * expElogBeta_wk
    for _ in 0..max_iters {
        let psi_total = digamma(gamma.iter().sum::<f64>());
        for kidx in 0..kk {
            exp_elog_theta[kidx] = (digamma(gamma[kidx]) - psi_total).exp();
        }
        let mut new_gamma = vec![alpha; kk];
        for &(w, cnt) in &words {
            let row = &elog_beta[w as usize * kk..(w as usize + 1) * kk];
            let mut norm = 1e-100;
            for kidx in 0..kk {
                norm += exp_elog_theta[kidx] * row[kidx].exp();
            }
            let scale = cnt / norm;
            for kidx in 0..kk {
                new_gamma[kidx] += scale * exp_elog_theta[kidx] * row[kidx].exp();
            }
        }
        // Relative mean change: scale-invariant in K so the number of
        // coordinate-ascent passes does not shrink as K grows (the cost
        // per pass is O(K * uniq_words), matching Hoffman's complexity).
        // The default config disables early stopping (tol = 0) so the
        // per-token cost is exactly O(inner_iters * K), reproducing the
        // paper's measured superlinear runtime growth in K.
        let total: f64 = new_gamma.iter().sum();
        let delta: f64 =
            gamma.iter().zip(&new_gamma).map(|(a, b)| (a - b).abs()).sum::<f64>() / total;
        gamma = new_gamma;
        if delta < tol {
            break;
        }
    }
    gamma
}

/// Train online VB over the corpus.
pub fn train(cfg: &OnlineConfig, corpus: &Corpus) -> Result<OnlineModel> {
    if corpus.num_docs() == 0 {
        return Err(Error::Config("empty corpus".into()));
    }
    let k = cfg.num_topics;
    let kk = k as usize;
    let v = corpus.vocab_size;
    let alpha = if cfg.alpha > 0.0 { cfg.alpha } else { 1.0 / k as f64 };
    let eta = if cfg.eta > 0.0 { cfg.eta } else { 1.0 / k as f64 };
    let d_total = corpus.num_docs() as f64;
    let mut rng = Pcg64::new(cfg.seed);

    // λ init ~ Gamma(100, 1/100) as in Hoffman's reference code.
    let mut lambda: Vec<f64> =
        (0..v as usize * kk).map(|_| rng.gamma(100.0) / 100.0).collect();

    let report = Report::new();
    let mut update = 0u64;
    let mut order: Vec<usize> = (0..corpus.num_docs()).collect();

    for epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        for batch in order.chunks(cfg.batch_size.max(1)) {
            let sw = Stopwatch::new();
            let elog_beta = expect_log_beta(&lambda, v, k);
            // Parallel per-document E-step; accumulate sufficient stats.
            let stats: Vec<Vec<(u32, Vec<f64>)>> =
                parallel_chunks(batch, cfg.workers, |_, chunk| {
                    let mut local: Vec<(u32, Vec<f64>)> = Vec::new();
                    for &d in chunk {
                        let tokens = &corpus.docs[d].tokens;
                        let gamma = fit_gamma(
                            tokens,
                            &elog_beta,
                            k,
                            alpha,
                            cfg.inner_iters,
                            cfg.gamma_tol,
                        );
                        // Recompute phi contributions: sstats_wk +=
                        // count * normalized resp.
                        let psi_total = digamma(gamma.iter().sum::<f64>());
                        let exp_theta: Vec<f64> =
                            gamma.iter().map(|&g| (digamma(g) - psi_total).exp()).collect();
                        let mut ids: Vec<u32> = tokens.clone();
                        ids.sort_unstable();
                        let mut uniq: Vec<(u32, f64)> = Vec::new();
                        for &w in &ids {
                            match uniq.last_mut() {
                                Some((lw, c)) if *lw == w => *c += 1.0,
                                _ => uniq.push((w, 1.0)),
                            }
                        }
                        for (w, cnt) in uniq {
                            let row = &elog_beta[w as usize * kk..(w as usize + 1) * kk];
                            let mut contrib = vec![0.0f64; kk];
                            let mut norm = 1e-100;
                            for kidx in 0..kk {
                                contrib[kidx] = exp_theta[kidx] * row[kidx].exp();
                                norm += contrib[kidx];
                            }
                            let scale = cnt / norm;
                            for c in contrib.iter_mut() {
                                *c *= scale;
                            }
                            local.push((w, contrib));
                        }
                    }
                    local
                });
            // M-step: stochastic natural-gradient update of λ.
            update += 1;
            let rho = (cfg.tau0 + update as f64).powf(-cfg.kappa);
            let batch_scale = d_total / batch.len() as f64;
            // λ̂ = η + D/|B| * sstats; blend. Decay all entries toward η
            // first, then add the sparse batch statistics.
            for x in lambda.iter_mut() {
                *x = (1.0 - rho) * *x + rho * eta;
            }
            for local in stats {
                for (w, contrib) in local {
                    let base = w as usize * kk;
                    for (kidx, &c) in contrib.iter().enumerate() {
                        lambda[base + kidx] += rho * batch_scale * c;
                    }
                }
            }
            report.push(
                Row::new()
                    .set("epoch", epoch as f64)
                    .set("update", update as f64)
                    .set("rho", rho)
                    .set("seconds", sw.secs()),
            );
        }
    }

    Ok(OnlineModel { k, v, lambda, alpha, eta, report })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synth::{generate, SynthConfig};

    fn corpus() -> Corpus {
        generate(&SynthConfig {
            num_docs: 150,
            vocab_size: 200,
            num_topics: 4,
            avg_doc_len: 25.0,
            seed: 55,
            ..Default::default()
        })
    }

    fn cfg() -> OnlineConfig {
        OnlineConfig {
            num_topics: 6,
            epochs: 2,
            batch_size: 32,
            workers: 3,
            inner_iters: 30,
            ..Default::default()
        }
    }

    #[test]
    fn trains_and_evaluates() {
        let c = corpus();
        let m = train(&cfg(), &c).unwrap();
        let p = m.perplexity(&c, 3);
        assert!(p.is_finite() && p > 0.0);
        assert!(p < c.vocab_size as f64, "perplexity {p} should beat uniform");
    }

    #[test]
    fn more_training_helps() {
        let c = corpus();
        let mut short = cfg();
        short.epochs = 1;
        short.batch_size = 150; // one coarse update
        let m_short = train(&short, &c).unwrap();
        let mut long = cfg();
        long.epochs = 4;
        let m_long = train(&long, &c).unwrap();
        let p_short = m_short.perplexity(&c, 3);
        let p_long = m_long.perplexity(&c, 3);
        assert!(p_long < p_short, "{p_short} -> {p_long}");
    }

    #[test]
    fn phi_normalizes() {
        let c = corpus();
        let m = train(&cfg(), &c).unwrap();
        let phi = m.phi_vk();
        for k in 0..6usize {
            let s: f64 = (0..m.v as usize).map(|w| phi[w * 6 + k]).sum();
            assert!((s - 1.0).abs() < 1e-9, "topic {k} sums to {s}");
        }
    }

    #[test]
    fn lambda_stays_positive() {
        let c = corpus();
        let m = train(&cfg(), &c).unwrap();
        assert!(m.lambda.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn gamma_fit_converges_on_peaked_doc() {
        // A document of one repeated word must concentrate gamma on the
        // topic that loves that word.
        let k = 3u32;
        let v = 5u32;
        let mut lambda = vec![1.0f64; 15];
        // Topic 0 strongly prefers word 2.
        lambda[2 * 3] = 500.0;
        let elog = expect_log_beta(&lambda, v, k);
        let tokens = vec![2u32; 30];
        let gamma = fit_gamma(&tokens, &elog, k, 0.33, 100, 1e-4);
        let total: f64 = gamma.iter().sum();
        assert!(gamma[0] / total > 0.8, "gamma {gamma:?}");
    }
}
