//! Shuffle-write accounting.
//!
//! Our baselines run in one process, so nothing is literally shuffled.
//! To reproduce Table 1's shuffle-write column we account for the bytes
//! Spark's execution of the same algorithm would write between stages:
//!
//! - **EM (GraphX `EMLDAOptimizer`)**: every iteration re-aggregates the
//!   topic responsibilities along the document–word bipartite graph and
//!   re-materializes both vertex tables. Shuffled bytes per iteration ≈
//!   `8 * K * (D + V + E)` where `E` is the number of distinct
//!   (doc, word) edges — K doubles per vertex state and per edge
//!   message. This is linear in both corpus size and K, which is exactly
//!   the shape of the paper's measurements (6.2 GB at 10 %/K=20 growing
//!   to 23.9 GB at 10 %/K=80).
//! - **Online LDA**: sufficient statistics are `treeAggregate`d to the
//!   driver — no shuffle write (the paper reports 0).
//! - **Ours**: the parameter server replaces shuffles entirely — 0 by
//!   construction; network traffic is push/pull messages, measured
//!   separately by [`crate::net::stats`].

use crate::corpus::dataset::Corpus;

/// Distinct (document, word) edge count of the bipartite graph.
pub fn distinct_edges(corpus: &Corpus) -> u64 {
    let mut edges = 0u64;
    let mut seen = std::collections::HashSet::new();
    for doc in &corpus.docs {
        seen.clear();
        for &w in &doc.tokens {
            if seen.insert(w) {
                edges += 1;
            }
        }
    }
    edges
}

/// Bytes the GraphX EM implementation would shuffle in one iteration.
pub fn em_shuffle_bytes_per_iter(corpus: &Corpus, k: u32, edges: u64) -> u64 {
    let d = corpus.num_docs() as u64;
    let v = corpus.vocab_size as u64;
    8 * k as u64 * (d + v + edges)
}

/// Total EM shuffle bytes over a run.
pub fn em_shuffle_bytes(corpus: &Corpus, k: u32, iterations: u32) -> u64 {
    let edges = distinct_edges(corpus);
    em_shuffle_bytes_per_iter(corpus, k, edges) * iterations as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::dataset::Document;

    fn corpus() -> Corpus {
        Corpus {
            docs: vec![
                Document { tokens: vec![0, 1, 0, 2] }, // 3 distinct
                Document { tokens: vec![1, 1] },       // 1 distinct
            ],
            vocab_size: 3,
            vocab: vec![],
        }
    }

    #[test]
    fn edge_count_distinct_per_doc() {
        assert_eq!(distinct_edges(&corpus()), 4);
    }

    #[test]
    fn bytes_linear_in_k() {
        let c = corpus();
        let b20 = em_shuffle_bytes(&c, 20, 10);
        let b40 = em_shuffle_bytes(&c, 40, 10);
        assert_eq!(b40, 2 * b20);
    }

    #[test]
    fn bytes_grow_with_corpus() {
        let small = corpus();
        let mut big = corpus();
        big.docs.extend(small.docs.clone());
        assert!(em_shuffle_bytes(&big, 20, 1) > em_shuffle_bytes(&small, 20, 1));
    }
}
