//! On-disk segment format of the per-shard write-ahead log.
//!
//! A WAL directory holds two kinds of segment files:
//!
//! - `log-<base_seq>.wal` — an append-only run of records whose
//!   sequence numbers start at `base_seq`;
//! - `snap-<upto_seq>.wal` — a compactor-written snapshot of the whole
//!   shard state as of sequence `upto_seq` (every record inside carries
//!   that sequence number).
//!
//! Every file opens with a fixed header and then carries length-prefixed,
//! checksummed records:
//!
//! ```text
//! header:  magic u32 | version u8 | kind u8 | shard u32 | base_seq u64
//! record:  len u32 | fnv1a64(seq ++ payload) u64 | seq u64 | payload
//! ```
//!
//! Reads are **torn-tail tolerant**, mirroring
//! [`crate::lda::checkpoint::Checkpoint::load_latest`]'s
//! skip-to-newest-valid semantics: a short or checksum-failing record
//! ends the scan at the last good record instead of erroring — exactly
//! what a `kill -9` mid-append leaves behind. Snapshot files are written
//! to a temp name and atomically renamed, and recovery additionally
//! requires their terminal marker record, so a torn snapshot is skipped
//! in favor of an older valid one.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use crate::log_warn;
use crate::util::error::{Error, Result};

/// `b"GLWA"` little-endian: glint WAL.
pub const MAGIC: u32 = 0x4157_4c47;
/// Format version.
pub const VERSION: u8 = 1;

/// Segment kind tag in the file header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentKind {
    /// Append-only run of write records.
    Log,
    /// Snapshot-of-state written by the compactor.
    Snapshot,
}

impl SegmentKind {
    fn tag(self) -> u8 {
        match self {
            SegmentKind::Log => 0,
            SegmentKind::Snapshot => 1,
        }
    }

    fn from_tag(t: u8) -> Result<SegmentKind> {
        match t {
            0 => Ok(SegmentKind::Log),
            1 => Ok(SegmentKind::Snapshot),
            _ => Err(Error::Decode(format!("bad wal segment kind {t}"))),
        }
    }
}

/// Parsed segment header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentHeader {
    /// Log or snapshot.
    pub kind: SegmentKind,
    /// Shard this segment belongs to (cross-wiring guard).
    pub shard: u32,
    /// First sequence number (log) or snapshot-as-of sequence (snap).
    pub base_seq: u64,
}

/// Header length in bytes.
pub const HEADER_LEN: usize = 4 + 1 + 1 + 4 + 8;
/// Per-record framing overhead in bytes (len + checksum + seq).
pub const RECORD_OVERHEAD: usize = 4 + 8 + 8;

/// One decoded record: `(seq, payload)`.
pub type RawRecord = (u64, Vec<u8>);

/// 64-bit FNV-1a over the record's seq (LE bytes) then payload.
pub fn checksum(seq: u64, payload: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for b in seq.to_le_bytes() {
        eat(b);
    }
    for &b in payload {
        eat(b);
    }
    h
}

/// File name of a log segment whose first record is `base_seq`.
pub fn log_name(base_seq: u64) -> String {
    format!("log-{base_seq:020}.wal")
}

/// File name of a snapshot as of `upto_seq`.
pub fn snap_name(upto_seq: u64) -> String {
    format!("snap-{upto_seq:020}.wal")
}

/// Parse a segment file name into `(kind, seq)`; `None` for foreign
/// files (temp files, editor droppings) so directory scans skip them.
pub fn parse_name(name: &str) -> Option<(SegmentKind, u64)> {
    let (kind, rest) = if let Some(r) = name.strip_prefix("log-") {
        (SegmentKind::Log, r)
    } else if let Some(r) = name.strip_prefix("snap-") {
        (SegmentKind::Snapshot, r)
    } else {
        return None;
    };
    let digits = rest.strip_suffix(".wal")?;
    if digits.len() != 20 || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok().map(|seq| (kind, seq))
}

fn encode_header(h: &SegmentHeader) -> [u8; HEADER_LEN] {
    let mut buf = [0u8; HEADER_LEN];
    buf[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    buf[4] = VERSION;
    buf[5] = h.kind.tag();
    buf[6..10].copy_from_slice(&h.shard.to_le_bytes());
    buf[10..18].copy_from_slice(&h.base_seq.to_le_bytes());
    buf
}

fn decode_header(buf: &[u8]) -> Result<SegmentHeader> {
    if buf.len() < HEADER_LEN {
        return Err(Error::Decode(format!("wal header truncated at {} bytes", buf.len())));
    }
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(Error::Decode(format!("bad wal magic {magic:#x}")));
    }
    if buf[4] != VERSION {
        return Err(Error::Decode(format!("unsupported wal version {}", buf[4])));
    }
    Ok(SegmentHeader {
        kind: SegmentKind::from_tag(buf[5])?,
        shard: u32::from_le_bytes(buf[6..10].try_into().unwrap()),
        base_seq: u64::from_le_bytes(buf[10..18].try_into().unwrap()),
    })
}

/// Append-side handle to one open segment file.
pub struct SegmentWriter {
    path: PathBuf,
    file: BufWriter<File>,
    /// Bytes written so far, header included (drives rotation).
    pub bytes: u64,
    /// Records written.
    pub records: u64,
}

impl SegmentWriter {
    /// Create a fresh segment at `path` and write its header.
    pub fn create(path: &Path, header: SegmentHeader) -> Result<SegmentWriter> {
        let file = OpenOptions::new().write(true).create_new(true).open(path)?;
        let mut w = SegmentWriter {
            path: path.to_path_buf(),
            file: BufWriter::new(file),
            bytes: 0,
            records: 0,
        };
        w.file.write_all(&encode_header(&header))?;
        w.bytes += HEADER_LEN as u64;
        Ok(w)
    }

    /// Append one framed record (buffered; durable only after
    /// [`SegmentWriter::sync`]).
    pub fn append(&mut self, seq: u64, payload: &[u8]) -> Result<()> {
        self.file.write_all(&(payload.len() as u32).to_le_bytes())?;
        self.file.write_all(&checksum(seq, payload).to_le_bytes())?;
        self.file.write_all(&seq.to_le_bytes())?;
        self.file.write_all(payload)?;
        self.bytes += (RECORD_OVERHEAD + payload.len()) as u64;
        self.records += 1;
        Ok(())
    }

    /// Flush buffers and fsync to disk (the group-commit point).
    pub fn sync(&mut self) -> Result<()> {
        self.file.flush()?;
        self.file.get_ref().sync_data()?;
        Ok(())
    }

    /// Throw away buffered-but-unflushed bytes (crash injection for the
    /// model suite): a dead process never flushes, so the injected
    /// "kill -9" must not let this writer's eventual `Drop` leak the
    /// lost records back into the file. Re-points the writer at a fresh
    /// handle and closes the old one *without* flushing.
    #[cfg(feature = "model")]
    pub fn discard_buffered(&mut self) -> Result<()> {
        let file = OpenOptions::new().append(true).open(&self.path)?;
        let old = std::mem::replace(&mut self.file, BufWriter::new(file));
        let (old_file, _lost) = old.into_parts();
        drop(old_file); // closed un-flushed: the buffered tail is gone
        Ok(())
    }

    /// The segment's path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// A fully scanned segment: header, records up to the first torn or
/// corrupt frame, and whether the scan reached a clean end-of-file.
pub struct ScannedSegment {
    /// Parsed header.
    pub header: SegmentHeader,
    /// Records in file order, ending at the last valid frame.
    pub records: Vec<RawRecord>,
    /// False when the scan stopped at a torn/corrupt frame before EOF.
    pub clean: bool,
}

/// Read a segment, tolerating a torn tail: the scan stops at the first
/// short or checksum-failing record and reports everything before it.
/// Only a bad *header* is a hard error (the file is not a WAL segment).
pub fn scan(path: &Path) -> Result<ScannedSegment> {
    let mut buf = Vec::new();
    File::open(path)?.read_to_end(&mut buf)?;
    let header = decode_header(&buf)?;
    let mut records = Vec::new();
    let mut pos = HEADER_LEN;
    let mut clean = true;
    while pos < buf.len() {
        if pos + RECORD_OVERHEAD > buf.len() {
            clean = false;
            break;
        }
        let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
        let want = u64::from_le_bytes(buf[pos + 4..pos + 12].try_into().unwrap());
        let seq = u64::from_le_bytes(buf[pos + 12..pos + 20].try_into().unwrap());
        let start = pos + RECORD_OVERHEAD;
        let Some(end) = start.checked_add(len).filter(|&e| e <= buf.len()) else {
            clean = false;
            break;
        };
        let payload = &buf[start..end];
        if checksum(seq, payload) != want {
            clean = false;
            break;
        }
        records.push((seq, payload.to_vec()));
        pos = end;
    }
    if !clean {
        log_warn!(
            "wal segment {} has a torn tail after {} record(s); replaying the valid prefix",
            path.display(),
            records.len()
        );
    }
    Ok(ScannedSegment { header, records, clean })
}

/// Write a complete snapshot segment atomically: records go to a temp
/// file which is fsynced and renamed into place, so a crash mid-write
/// never leaves a half-snapshot under the real name.
pub fn write_snapshot(
    dir: &Path,
    shard: u32,
    upto_seq: u64,
    payloads: &[Vec<u8>],
) -> Result<PathBuf> {
    let final_path = dir.join(snap_name(upto_seq));
    let tmp_path = dir.join(format!(".tmp-{}", snap_name(upto_seq)));
    let _ = std::fs::remove_file(&tmp_path);
    {
        let mut w = SegmentWriter::create(
            &tmp_path,
            SegmentHeader { kind: SegmentKind::Snapshot, shard, base_seq: upto_seq },
        )?;
        for p in payloads {
            w.append(upto_seq, p)?;
        }
        w.sync()?;
    }
    std::fs::rename(&tmp_path, &final_path)?;
    Ok(final_path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("glint-wal-seg-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_records() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join(log_name(1));
        let header = SegmentHeader { kind: SegmentKind::Log, shard: 3, base_seq: 1 };
        let mut w = SegmentWriter::create(&path, header).unwrap();
        for seq in 1..=5u64 {
            w.append(seq, &vec![seq as u8; seq as usize * 10]).unwrap();
        }
        w.sync().unwrap();
        let scanned = scan(&path).unwrap();
        assert_eq!(scanned.header, header);
        assert!(scanned.clean);
        assert_eq!(scanned.records.len(), 5);
        for (i, (seq, payload)) in scanned.records.iter().enumerate() {
            assert_eq!(*seq, i as u64 + 1);
            assert_eq!(payload.len(), (i + 1) * 10);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_stops_at_last_valid_record() {
        let dir = tmp_dir("torn");
        let path = dir.join(log_name(1));
        let mut w = SegmentWriter::create(
            &path,
            SegmentHeader { kind: SegmentKind::Log, shard: 0, base_seq: 1 },
        )
        .unwrap();
        w.append(1, b"first").unwrap();
        w.append(2, b"second").unwrap();
        w.sync().unwrap();
        // Simulate a kill -9 mid-append: a frame whose payload is cut off.
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&100u32.to_le_bytes()).unwrap();
            f.write_all(&0u64.to_le_bytes()).unwrap();
            f.write_all(&3u64.to_le_bytes()).unwrap();
            f.write_all(b"only-part-of-the-payload").unwrap();
        }
        let scanned = scan(&path).unwrap();
        assert!(!scanned.clean);
        assert_eq!(scanned.records.len(), 2);
        assert_eq!(scanned.records[1], (2, b"second".to_vec()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_record_stops_scan() {
        let dir = tmp_dir("corrupt");
        let path = dir.join(log_name(7));
        let mut w = SegmentWriter::create(
            &path,
            SegmentHeader { kind: SegmentKind::Log, shard: 0, base_seq: 7 },
        )
        .unwrap();
        w.append(7, b"good").unwrap();
        w.append(8, b"flipped").unwrap();
        w.sync().unwrap();
        // Flip one payload byte of the second record.
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let scanned = scan(&path).unwrap();
        assert!(!scanned.clean);
        assert_eq!(scanned.records, vec![(7, b"good".to_vec())]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_header_is_a_hard_error() {
        let dir = tmp_dir("header");
        let path = dir.join(log_name(1));
        std::fs::write(&path, b"not a wal segment at all").unwrap();
        assert!(scan(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn names_roundtrip_and_sort() {
        assert_eq!(parse_name(&log_name(42)), Some((SegmentKind::Log, 42)));
        assert_eq!(parse_name(&snap_name(7)), Some((SegmentKind::Snapshot, 7)));
        assert_eq!(parse_name(".tmp-snap-00000000000000000007.wal"), None);
        assert_eq!(parse_name("log-abc.wal"), None);
        assert_eq!(parse_name("checkpoint-3.bin"), None);
        // Zero-padded names sort lexicographically in seq order.
        assert!(log_name(9) < log_name(10));
    }

    #[test]
    fn snapshot_written_atomically() {
        let dir = tmp_dir("snap");
        let payloads = vec![b"state-a".to_vec(), b"state-b".to_vec()];
        let path = write_snapshot(&dir, 2, 99, &payloads).unwrap();
        assert_eq!(path.file_name().unwrap().to_str().unwrap(), snap_name(99));
        let scanned = scan(&path).unwrap();
        assert!(scanned.clean);
        assert_eq!(scanned.header.kind, SegmentKind::Snapshot);
        assert_eq!(scanned.header.base_seq, 99);
        assert_eq!(
            scanned.records,
            vec![(99, b"state-a".to_vec()), (99, b"state-b".to_vec())]
        );
        // No temp file left behind.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().file_name().to_string_lossy().starts_with(".tmp-")
            })
            .collect();
        assert!(leftovers.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
