//! Durable per-shard write-ahead log with group commit and compaction.
//!
//! Every state-mutating request a shard applies (`CreateMatrix`,
//! `Push*`, `Forget`, `DeleteMatrix`) is appended to this log before the
//! server acknowledges, so a `kill -9`'d shard process recovers its
//! count tables by replaying the log on restart — the exactly-once push
//! uids recorded in the log flow through the same dedup window on
//! replay, so recovery is idempotent by construction.
//!
//! # Group commit
//!
//! [`ShardWal::append`] never touches the disk: it assigns the record a
//! sequence number and enqueues it for a dedicated **committer thread**,
//! which drains whatever accumulated, writes it as one batch and fsyncs
//! once ([`WalOptions::commit_window`] bounds how long a lone record
//! waits for company). Push acknowledgements do *not* wait for the
//! fsync — durability is window-bounded (a crash can lose at most the
//! last un-synced window), which keeps hot-path push latency flat while
//! replication and recovery only ever observe the *committed* prefix
//! ([`ShardWal::committed`]). [`ShardWal::sync`] is the explicit
//! barrier, used at snapshot and shutdown time.
//!
//! # Segments and compaction
//!
//! The log is segmented into bounded files
//! ([`WalOptions::segment_bytes`]); once enough sealed segments pile up,
//! the shard folds the *entire current state* (count matrices + dedup
//! window + uid counter) into one snapshot segment and deletes every
//! log segment behind it — replay cost and disk footprint stay
//! proportional to live state, not to history. Cold epoch tables the
//! coordinator fences off are reclaimed through the `DeleteMatrix`
//! op, which is itself logged, so compaction drops their bytes
//! entirely.
//!
//! # Replication feed
//!
//! [`ShardWal::read_from`] serves the committed prefix to a backup
//! replica: a poller that is behind the compaction horizon receives a
//! `reset` batch carrying the newest snapshot, then streams log records
//! from there (see `ps::server`'s `ReplPoll`/`ReplApply` handling).

pub mod segment;

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

// The group-commit handoff (append queue, committer condvar, durable
// frontier) runs on the sync_shim so the model checker can explore its
// interleavings — including the committer thread itself, which becomes a
// virtual task under `--features model` (`tests/model.rs`, `wal-*`
// models). Disk writes are real in both builds.
use crate::log_warn;
use crate::ps::messages::{Data, Dtype, Layout};
use crate::util::codec::{Reader, Writer};
use crate::util::error::{Error, Result};
use crate::util::sync_shim::atomic::{AtomicU64, Ordering};
use crate::util::sync_shim::thread::JoinHandle;
use crate::util::sync_shim::{thread, Condvar, Mutex};
use segment::{
    log_name, parse_name, scan, write_snapshot, RawRecord, SegmentHeader, SegmentKind,
    SegmentWriter, RECORD_OVERHEAD,
};

/// Knobs of one shard's WAL.
#[derive(Debug, Clone)]
pub struct WalOptions {
    /// Rotate the active log segment once it exceeds this many bytes.
    pub segment_bytes: u64,
    /// Longest a lone queued record waits before the committer fsyncs
    /// it anyway (the durability window).
    pub commit_window: Duration,
    /// Sealed log segments that trigger a compaction into a snapshot.
    pub compact_after: usize,
    /// Deterministic crash injection for the model checker: the
    /// committer thread "dies" immediately after its `n+1`-th record
    /// write (so `Some(0)` kills it after the very next one), in the
    /// window between writing to the segment and fsyncing it — the
    /// exact window a real `kill -9` hits, where records were handed to
    /// the group-commit queue (and their pushes already acked) but
    /// never became durable. The hook discards the un-flushed bytes (a
    /// dead process never flushes its buffers), publishes nothing as
    /// committed, and unblocks `sync` waiters through the shutdown
    /// flag, so the model suite can assert that recovery replays
    /// exactly the durable prefix. Per-WAL on purpose: a process-wide
    /// switch would leak crashes into unrelated concurrently-running
    /// tests.
    #[cfg(feature = "model")]
    pub crash_after_writes: Option<u64>,
}

impl Default for WalOptions {
    fn default() -> Self {
        WalOptions {
            segment_bytes: 1 << 20,
            commit_window: Duration::from_millis(2),
            compact_after: 4,
            #[cfg(feature = "model")]
            crash_after_writes: None,
        }
    }
}

/// One logical WAL record.
///
/// `Write` carries a verbatim-encoded [`crate::ps::messages::Request`]
/// (the apply path re-decodes it on replay, so log replay and live
/// traffic share one code path). The `Snap*` variants are emitted only
/// by the compactor and describe a full shard state as of the
/// snapshot's sequence number.
#[derive(Debug, Clone, PartialEq)]
pub enum WalPayload {
    /// A state-mutating request, encoded exactly as it came off the wire.
    Write(Vec<u8>),
    /// Snapshot: a matrix exists with this shape.
    SnapMatrix {
        /// Matrix id.
        id: u32,
        /// Global row count.
        rows: u64,
        /// Column count.
        cols: u32,
        /// Element type.
        dtype: Dtype,
        /// Storage layout.
        layout: Layout,
    },
    /// Snapshot: a chunk of one matrix's non-zero entries, as absolute
    /// values at global `(row, col)` coordinates.
    SnapRows {
        /// Matrix id.
        matrix: u32,
        /// Global rows (one per entry).
        rows: Vec<u64>,
        /// Columns (one per entry).
        cols: Vec<u32>,
        /// Absolute values.
        values: Data,
    },
    /// Snapshot: the dedup window's un-forgotten uids in FIFO order.
    SnapDedup {
        /// Applied-but-not-forgotten push uids, oldest first.
        uids: Vec<u64>,
    },
    /// Snapshot terminal marker: the shard's next-uid counter. Always
    /// the last record of a snapshot — its presence is how recovery
    /// tells a complete snapshot from a torn one.
    SnapNextUid(u64),
}

const P_WRITE: u8 = 1;
const P_SNAP_MATRIX: u8 = 2;
const P_SNAP_ROWS: u8 = 3;
const P_SNAP_DEDUP: u8 = 4;
const P_SNAP_NEXT_UID: u8 = 5;

impl WalPayload {
    /// Serialize to record bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            WalPayload::Write(req) => {
                w.u8(P_WRITE);
                w.bytes(req);
            }
            WalPayload::SnapMatrix { id, rows, cols, dtype, layout } => {
                w.u8(P_SNAP_MATRIX);
                w.u32(*id);
                w.u64(*rows);
                w.u32(*cols);
                w.u8(match dtype {
                    Dtype::I64 => 0,
                    Dtype::F32 => 1,
                });
                w.u8(layout.tag());
            }
            WalPayload::SnapRows { matrix, rows, cols, values } => {
                w.u8(P_SNAP_ROWS);
                w.u32(*matrix);
                w.slice_varint(rows);
                w.slice_varint_u32(cols);
                values.encode(&mut w);
            }
            WalPayload::SnapDedup { uids } => {
                w.u8(P_SNAP_DEDUP);
                w.slice_varint(uids);
            }
            WalPayload::SnapNextUid(v) => {
                w.u8(P_SNAP_NEXT_UID);
                w.u64(*v);
            }
        }
        w.into_bytes()
    }

    /// Parse from record bytes.
    pub fn decode(bytes: &[u8]) -> Result<WalPayload> {
        let mut r = Reader::new(bytes);
        let payload = match r.u8()? {
            P_WRITE => WalPayload::Write(r.bytes()?),
            P_SNAP_MATRIX => WalPayload::SnapMatrix {
                id: r.u32()?,
                rows: r.u64()?,
                cols: r.u32()?,
                dtype: match r.u8()? {
                    0 => Dtype::I64,
                    1 => Dtype::F32,
                    t => return Err(Error::Decode(format!("bad wal dtype tag {t}"))),
                },
                layout: Layout::from_tag(r.u8()?)?,
            },
            P_SNAP_ROWS => WalPayload::SnapRows {
                matrix: r.u32()?,
                rows: r.slice_varint()?,
                cols: r.slice_varint_u32()?,
                values: Data::decode(&mut r)?,
            },
            P_SNAP_DEDUP => WalPayload::SnapDedup { uids: r.slice_varint()? },
            P_SNAP_NEXT_UID => WalPayload::SnapNextUid(r.u64()?),
            t => return Err(Error::Decode(format!("bad wal payload tag {t}"))),
        };
        Ok(payload)
    }
}

/// True when `bytes` encode the snapshot terminal marker.
fn is_terminal_marker(bytes: &[u8]) -> bool {
    bytes.first() == Some(&P_SNAP_NEXT_UID)
}

/// WAL counters surfaced through `ShardInfo`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WalStats {
    /// Records appended (recovered + new).
    pub records: u64,
    /// Bytes resident on disk across all segments.
    pub bytes: u64,
    /// fsync batches the committer has written (group-commit count).
    pub commit_batches: u64,
}

/// A slice of the committed log served to a replication poller.
#[derive(Debug, Clone, PartialEq)]
pub struct WalSlice {
    /// The poller's cursor predates the compaction horizon: `records`
    /// carry the full newest snapshot and the replica must rebuild from
    /// scratch before streaming on.
    pub reset: bool,
    /// Cursor for the next poll (first sequence not included here).
    pub next: u64,
    /// Highest committed sequence at read time (lag = `tip + 1 - next`).
    pub tip: u64,
    /// `(seq, payload)` records in order.
    pub records: Vec<RawRecord>,
}

struct Queue {
    pending: VecDeque<(u64, Vec<u8>)>,
    next_seq: u64,
    shutdown: bool,
}

struct FileState {
    active: SegmentWriter,
    sealed: Vec<(u64, PathBuf)>,
    snapshot: Option<(u64, PathBuf)>,
}

struct Inner {
    shard: u32,
    dir: PathBuf,
    opts: WalOptions,
    queue: Mutex<Queue>,
    /// Committer waits here for work.
    work: Condvar,
    /// `sync` callers wait here for the committed frontier to advance.
    durable: Condvar,
    committed: AtomicU64,
    files: Mutex<FileState>,
    records: AtomicU64,
    bytes: AtomicU64,
    batches: AtomicU64,
}

/// One shard's write-ahead log. Appends are non-blocking (queued for
/// the group-commit thread); reads ([`ShardWal::read_from`]) see only
/// the committed prefix.
pub struct ShardWal {
    inner: Arc<Inner>,
    committer: Mutex<Option<JoinHandle<()>>>,
}

impl ShardWal {
    /// Open (or create) the WAL at `dir`, recovering whatever a previous
    /// life left behind: the newest *valid* snapshot (corrupt or torn
    /// ones are skipped with a warning, mirroring checkpoint loading)
    /// plus every committed log record after it, in order. Returns the
    /// ready-to-append WAL and the records to replay.
    pub fn open(dir: &Path, shard: u32, opts: WalOptions) -> Result<(ShardWal, Vec<RawRecord>)> {
        std::fs::create_dir_all(dir)?;
        let mut logs: Vec<(u64, PathBuf)> = Vec::new();
        let mut snaps: Vec<(u64, PathBuf)> = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            match parse_name(name) {
                Some((SegmentKind::Log, seq)) => logs.push((seq, entry.path())),
                Some((SegmentKind::Snapshot, seq)) => snaps.push((seq, entry.path())),
                None => {}
            }
        }
        logs.sort_by_key(|&(seq, _)| seq);
        snaps.sort_by_key(|&(seq, _)| std::cmp::Reverse(seq));

        // Newest snapshot whose scan is clean and terminal-marked wins;
        // older ones are fallbacks, mirroring Checkpoint::load_latest.
        let mut replay: Vec<RawRecord> = Vec::new();
        let mut snapshot: Option<(u64, PathBuf)> = None;
        for (upto, path) in &snaps {
            match scan(path) {
                Ok(s)
                    if s.clean
                        && s.header.shard == shard
                        && s.records.last().is_some_and(|(_, p)| is_terminal_marker(p)) =>
                {
                    replay = s.records;
                    snapshot = Some((*upto, path.clone()));
                    break;
                }
                Ok(_) => {
                    log_warn!(
                        "wal snapshot {} is torn or foreign; falling back to an older one",
                        path.display()
                    );
                }
                Err(e) => {
                    log_warn!(
                        "wal snapshot {} is unreadable ({e}); falling back to an older one",
                        path.display()
                    );
                }
            }
        }
        let horizon = snapshot.as_ref().map(|&(upto, _)| upto).unwrap_or(0);

        // Log records strictly after the snapshot. Segments are walked
        // in base order and records must *chain* (each seq exactly one
        // past the last applied): duplicates are skipped, and a gap —
        // a torn tail whose lost records were never re-written by a
        // later life — ends the replay, because applying anything past
        // missing mutations would corrupt the counts. A previous
        // recovery leaves its predecessor's torn tail on disk and opens
        // a fresh segment at the next seq, so the common case chains
        // straight across segment boundaries.
        let mut last_seq = horizon;
        let mut sealed: Vec<(u64, PathBuf)> = Vec::new();
        let mut disk_bytes: u64 =
            snapshot.as_ref().map(|(_, p)| file_len(p)).unwrap_or(0);
        'segments: for (base, path) in &logs {
            let scanned = match scan(path) {
                Ok(s) if s.header.shard == shard && s.header.kind == SegmentKind::Log => s,
                Ok(_) => {
                    log_warn!(
                        "wal segment {} belongs to another shard; skipping it",
                        path.display()
                    );
                    continue;
                }
                Err(e) => {
                    log_warn!("wal segment {} is unreadable ({e}); skipping it", path.display());
                    continue;
                }
            };
            sealed.push((*base, path.clone()));
            disk_bytes += file_len(path);
            for (seq, payload) in scanned.records {
                if seq <= last_seq {
                    continue; // duplicate coverage (stale pre-compaction file)
                }
                if seq != last_seq + 1 {
                    log_warn!(
                        "wal shard {shard}: sequence gap {} -> {seq}; replay stops at \
                         the gap",
                        last_seq + 1
                    );
                    break 'segments;
                }
                last_seq = seq;
                replay.push((seq, payload));
            }
        }

        let next_seq = last_seq + 1;
        // A crash between creating a segment and appending to it can
        // leave an empty (or unreachable-suspect) file at exactly this
        // name; it holds nothing replayable, so reclaim the name.
        let active_path = dir.join(log_name(next_seq));
        if active_path.exists() {
            sealed.retain(|(_, p)| p != &active_path);
            std::fs::remove_file(&active_path)?;
        }
        let active = SegmentWriter::create(
            &active_path,
            SegmentHeader { kind: SegmentKind::Log, shard, base_seq: next_seq },
        )?;
        let inner = Arc::new(Inner {
            shard,
            dir: dir.to_path_buf(),
            opts,
            queue: Mutex::new(Queue {
                pending: VecDeque::new(),
                next_seq,
                shutdown: false,
            }),
            work: Condvar::new(),
            durable: Condvar::new(),
            committed: AtomicU64::new(last_seq),
            files: Mutex::new(FileState { active, sealed, snapshot }),
            records: AtomicU64::new(replay.len() as u64),
            bytes: AtomicU64::new(disk_bytes),
            batches: AtomicU64::new(0),
        });
        let committer = {
            let inner = Arc::clone(&inner);
            thread::Builder::new()
                .name(format!("glint-wal-{shard}"))
                .spawn(move || committer_loop(&inner))
                // PANIC-OK: committer spawn fails only on resource
                // exhaustion while opening the shard; there is no WAL
                // without it.
                .expect("spawn wal committer")
        };
        Ok((ShardWal { inner, committer: Mutex::new(Some(committer)) }, replay))
    }

    /// Enqueue one record for the committer; returns its sequence
    /// number. Never blocks on disk.
    ///
    /// SINGLE-WRITER: sequence numbers are dense because only the
    /// shard's one writer thread appends; concurrent appenders would
    /// still each get a unique seq (the queue lock allocates), but the
    /// apply order would no longer match seq order.
    pub fn append(&self, payload: &WalPayload) -> u64 {
        let bytes = payload.encode();
        let mut q = self.inner.queue.lock().unwrap();
        let seq = q.next_seq;
        q.next_seq += 1;
        q.pending.push_back((seq, bytes));
        drop(q);
        self.inner.work.notify_one();
        seq
    }

    /// Adopt `seq` as the already-durable frontier of an empty, freshly
    /// opened WAL: the next append gets `seq + 1`. A backup promoted to
    /// head uses this so its new log *continues* the replication
    /// sequence domain its standbys are cursored into — the snapshot it
    /// compacts right after lands at `upto = seq`, reachable by any
    /// `read_from` cursor at or below it, and caught-up standbys keep
    /// polling from `seq + 1` without a reset. No-op (with a warning)
    /// on a WAL that already holds records; state it could contradict.
    ///
    /// SINGLE-WRITER: call before the first append, on the thread that
    /// owns the shard's write path.
    pub fn adopt_frontier(&self, seq: u64) {
        let mut q = self.inner.queue.lock().unwrap();
        let committed = self.inner.committed.load(Ordering::Acquire);
        let empty = q.next_seq == 1 && q.pending.is_empty() && committed == 0;
        if !empty {
            log_warn!(
                "wal shard {}: refusing to adopt frontier {seq} over existing records (next {})",
                self.inner.shard,
                q.next_seq
            );
            return;
        }
        q.next_seq = seq + 1;
        self.inner.committed.store(seq, Ordering::Release);
    }

    /// Block until everything appended before this call is fsynced.
    /// Gives up (with a warning) if the committer stops making progress
    /// for ~10s — a failing disk must not wedge the shard forever.
    pub fn sync(&self) {
        let mut q = self.inner.queue.lock().unwrap();
        let target = q.next_seq - 1;
        let mut stalls = 0u32;
        while self.inner.committed.load(Ordering::Acquire) < target {
            if q.shutdown {
                break;
            }
            let before = self.inner.committed.load(Ordering::Acquire);
            let (guard, _) = self
                .inner
                .durable
                .wait_timeout(q, Duration::from_millis(20))
                .unwrap();
            q = guard;
            if self.inner.committed.load(Ordering::Acquire) > before {
                stalls = 0;
            } else {
                stalls += 1;
                if stalls > 500 {
                    log_warn!(
                        "wal shard {} sync stalled at seq {} (want {target}); giving up",
                        self.inner.shard,
                        before
                    );
                    break;
                }
            }
        }
    }

    /// Highest durably committed sequence number (0 = nothing yet).
    pub fn committed(&self) -> u64 {
        self.inner.committed.load(Ordering::Acquire)
    }

    /// Sealed log segments currently behind the active one (the
    /// compaction trigger input).
    pub fn sealed_segments(&self) -> usize {
        self.inner.files.lock().unwrap().sealed.len()
    }

    /// Counters for `ShardInfo`.
    pub fn stats(&self) -> WalStats {
        WalStats {
            records: self.inner.records.load(Ordering::Relaxed),
            bytes: self.inner.bytes.load(Ordering::Relaxed),
            commit_batches: self.inner.batches.load(Ordering::Relaxed),
        }
    }

    /// Fold the full shard state (as `Snap*` payloads, terminal marker
    /// last) into a snapshot segment at the current committed frontier
    /// and delete every log segment behind it.
    ///
    /// SINGLE-WRITER: must be called by the shard's one writer thread,
    /// with `payloads` describing the state after every appended record
    /// — [`ShardWal::sync`] runs first, so the snapshot never claims
    /// more than the disk holds.
    pub fn compact(&self, payloads: &[WalPayload]) -> Result<()> {
        debug_assert!(payloads.last().is_some_and(|p| matches!(p, WalPayload::SnapNextUid(_))));
        self.sync();
        let upto = self.inner.committed.load(Ordering::Acquire);
        let encoded: Vec<Vec<u8>> = payloads.iter().map(|p| p.encode()).collect();
        let mut files = self.inner.files.lock().unwrap();
        let snap_path = write_snapshot(&self.inner.dir, self.inner.shard, upto, &encoded)?;
        // Everything logged so far is <= upto (we are the writer thread
        // and just synced), so all log segments — sealed and active —
        // are superseded by the snapshot.
        for (_, path) in files.sealed.drain(..) {
            let _ = std::fs::remove_file(&path);
        }
        let old_active = files.active.path().to_path_buf();
        let next_base = upto + 1;
        let new_path = self.inner.dir.join(log_name(next_base));
        // The old active file may sit at exactly `new_path` (compaction
        // with zero new records), so remove before re-creating.
        let _ = std::fs::remove_file(&old_active);
        if new_path != old_active {
            let _ = std::fs::remove_file(&new_path);
        }
        files.active = SegmentWriter::create(
            &new_path,
            SegmentHeader { kind: SegmentKind::Log, shard: self.inner.shard, base_seq: next_base },
        )?;
        if let Some((_, old_snap)) = files.snapshot.replace((upto, snap_path.clone())) {
            if old_snap != snap_path {
                let _ = std::fs::remove_file(&old_snap);
            }
        }
        self.inner
            .bytes
            .store(file_len(&snap_path) + files.active.bytes, Ordering::Relaxed);
        Ok(())
    }

    /// Read committed records starting at sequence `from` (at most `max`
    /// log records). A cursor behind the compaction horizon gets a
    /// `reset` slice carrying the entire newest snapshot instead; the
    /// caller rebuilds from it and polls again from `next`.
    pub fn read_from(&self, from: u64, max: usize) -> Result<WalSlice> {
        let tip = self.inner.committed.load(Ordering::Acquire);
        let files = self.inner.files.lock().unwrap();
        if let Some((upto, snap_path)) = &files.snapshot {
            if from <= *upto {
                let scanned = scan(snap_path)?;
                return Ok(WalSlice {
                    reset: true,
                    next: upto + 1,
                    tip: tip.max(*upto),
                    records: scanned.records,
                });
            }
        }
        let mut records = Vec::new();
        let mut next = from;
        let active_path = files.active.path().to_path_buf();
        let active_base = active_path
            .file_name()
            .and_then(|n| n.to_str())
            .and_then(parse_name)
            .map(|(_, base)| base)
            .unwrap_or(0);
        let mut segments: Vec<(u64, PathBuf)> = files
            .sealed
            .iter()
            .map(|(base, path)| (*base, path.clone()))
            .collect();
        segments.push((active_base, active_path));
        drop(files);
        for (i, (_, path)) in segments.iter().enumerate() {
            // Skip segments that end before the cursor: a segment's
            // records all precede the next segment's base.
            if let Some(&(next_base, _)) = segments.get(i + 1) {
                if next_base <= from {
                    continue;
                }
            }
            let scanned = match scan(path) {
                Ok(s) => s,
                // The active segment may be mid-write; a torn tail scan
                // already tolerates that, but a transient open error
                // just ends this slice early.
                Err(_) => break,
            };
            for (seq, payload) in scanned.records {
                if seq >= from && seq <= tip && seq >= next {
                    records.push((seq, payload));
                    next = seq + 1;
                    if records.len() >= max {
                        return Ok(WalSlice { reset: false, next, tip, records });
                    }
                }
            }
        }
        Ok(WalSlice { reset: false, next, tip, records })
    }
}

impl Drop for ShardWal {
    fn drop(&mut self) {
        {
            let mut q = self.inner.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.inner.work.notify_all();
        if let Some(h) = self.committer.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn file_len(path: &Path) -> u64 {
    std::fs::metadata(path).map(|m| m.len()).unwrap_or(0)
}

/// The group-commit loop: drain whatever accumulated, write it as one
/// batch, fsync once, advance the committed frontier, repeat. A lone
/// record waits at most `commit_window` for company.
fn committer_loop(inner: &Inner) {
    #[cfg(feature = "model")]
    let mut crash_budget = inner.opts.crash_after_writes;
    loop {
        let batch: Vec<(u64, Vec<u8>)> = {
            let mut q = inner.queue.lock().unwrap();
            loop {
                if !q.pending.is_empty() {
                    break q.pending.drain(..).collect();
                }
                if q.shutdown {
                    return;
                }
                let (guard, _) =
                    inner.work.wait_timeout(q, inner.opts.commit_window).unwrap();
                q = guard;
            }
        };
        let mut files = inner.files.lock().unwrap();
        let mut written_through = None;
        for (seq, payload) in &batch {
            if files.active.bytes >= inner.opts.segment_bytes {
                if let Err(e) = rotate(inner, &mut files, *seq) {
                    log_warn!("wal shard {} failed to rotate segments: {e}", inner.shard);
                    break;
                }
            }
            if let Err(e) = files.active.append(*seq, payload) {
                log_warn!(
                    "wal shard {} failed to append record {seq}: {e}; dropping the batch tail",
                    inner.shard
                );
                break;
            }
            inner.records.fetch_add(1, Ordering::Relaxed);
            inner
                .bytes
                .fetch_add((RECORD_OVERHEAD + payload.len()) as u64, Ordering::Relaxed);
            written_through = Some(*seq);
            #[cfg(feature = "model")]
            if crash_tripped(&mut crash_budget) {
                // Injected kill -9 (see
                // [`WalOptions::crash_after_writes`]): die between the
                // segment write and the fsync. The buffered tail is
                // discarded (a dead process never flushes), nothing in
                // this batch is published as committed, and `sync`
                // waiters unblock through the shutdown flag.
                if let Err(e) = files.active.discard_buffered() {
                    log_warn!(
                        "wal shard {} crash hook failed to discard buffers: {e}",
                        inner.shard
                    );
                }
                drop(files);
                let mut q = inner.queue.lock().unwrap();
                q.shutdown = true;
                inner.durable.notify_all();
                inner.work.notify_all();
                return;
            }
        }
        let synced = files.active.sync();
        drop(files);
        if let Err(e) = synced {
            log_warn!("wal shard {} fsync failed: {e}", inner.shard);
        }
        if let Some(seq) = written_through {
            inner.committed.store(seq, Ordering::Release);
            inner.batches.fetch_add(1, Ordering::Relaxed);
        }
        let _q = inner.queue.lock().unwrap();
        inner.durable.notify_all();
    }
}

/// Consume one write from the injected crash budget; `true` = die now.
#[cfg(feature = "model")]
fn crash_tripped(budget: &mut Option<u64>) -> bool {
    match budget {
        None => false,
        Some(0) => true,
        Some(n) => {
            *n -= 1;
            false
        }
    }
}

/// Seal the active segment and open a fresh one whose base is `seq`.
fn rotate(inner: &Inner, files: &mut FileState, seq: u64) -> Result<()> {
    files.active.sync()?;
    let old_path = files.active.path().to_path_buf();
    let old_base = match parse_name(
        old_path.file_name().and_then(|n| n.to_str()).unwrap_or(""),
    ) {
        Some((_, base)) => base,
        None => 0,
    };
    let new_path = inner.dir.join(log_name(seq));
    let _ = std::fs::remove_file(&new_path);
    files.active = SegmentWriter::create(
        &new_path,
        SegmentHeader { kind: SegmentKind::Log, shard: inner.shard, base_seq: seq },
    )?;
    files.sealed.push((old_base, old_path));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("glint-wal-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn write_payload(n: u64) -> WalPayload {
        WalPayload::Write(vec![n as u8; 16])
    }

    fn snapshot_payloads(next_uid: u64) -> Vec<WalPayload> {
        vec![
            WalPayload::SnapMatrix {
                id: 1,
                rows: 10,
                cols: 4,
                dtype: Dtype::I64,
                layout: Layout::Dense,
            },
            WalPayload::SnapRows {
                matrix: 1,
                rows: vec![0, 3],
                cols: vec![1, 2],
                values: Data::I64(vec![5, -2]),
            },
            WalPayload::SnapDedup { uids: vec![9, 11] },
            WalPayload::SnapNextUid(next_uid),
        ]
    }

    #[test]
    fn payload_roundtrip() {
        for p in [
            write_payload(7),
            WalPayload::SnapMatrix {
                id: 3,
                rows: 1 << 33,
                cols: 1000,
                dtype: Dtype::F32,
                layout: Layout::Sparse,
            },
            WalPayload::SnapRows {
                matrix: 3,
                rows: vec![1, 2, 3],
                cols: vec![0, 5, 9],
                values: Data::F32(vec![0.5, -1.5, 2.0]),
            },
            WalPayload::SnapDedup { uids: vec![1, u64::MAX] },
            WalPayload::SnapNextUid(42),
        ] {
            assert_eq!(WalPayload::decode(&p.encode()).unwrap(), p);
        }
        assert!(WalPayload::decode(&[99]).is_err());
        assert!(WalPayload::decode(&[]).is_err());
    }

    #[test]
    fn append_sync_recover() {
        let dir = tmp_dir("basic");
        {
            let (wal, replay) = ShardWal::open(&dir, 0, WalOptions::default()).unwrap();
            assert!(replay.is_empty());
            for n in 1..=20u64 {
                assert_eq!(wal.append(&write_payload(n)), n);
            }
            wal.sync();
            assert_eq!(wal.committed(), 20);
            let stats = wal.stats();
            assert_eq!(stats.records, 20);
            assert!(stats.commit_batches >= 1);
            assert!(stats.commit_batches <= 20);
        }
        let (wal, replay) = ShardWal::open(&dir, 0, WalOptions::default()).unwrap();
        assert_eq!(replay.len(), 20);
        for (i, (seq, payload)) in replay.iter().enumerate() {
            assert_eq!(*seq, i as u64 + 1);
            assert_eq!(WalPayload::decode(payload).unwrap(), write_payload(*seq));
        }
        // Appends continue after the recovered frontier.
        assert_eq!(wal.append(&write_payload(21)), 21);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn segments_rotate_and_recover_in_order() {
        let dir = tmp_dir("rotate");
        let opts = WalOptions { segment_bytes: 256, ..WalOptions::default() };
        {
            let (wal, _) = ShardWal::open(&dir, 1, opts.clone()).unwrap();
            for n in 1..=64u64 {
                wal.append(&write_payload(n));
            }
            wal.sync();
            assert!(wal.sealed_segments() >= 2, "expected rotation");
        }
        let (_wal, replay) = ShardWal::open(&dir, 1, opts).unwrap();
        assert_eq!(replay.len(), 64);
        assert!(replay.windows(2).all(|w| w[0].0 + 1 == w[1].0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_replaces_logs_with_snapshot() {
        let dir = tmp_dir("compact");
        let opts = WalOptions { segment_bytes: 256, ..WalOptions::default() };
        {
            let (wal, _) = ShardWal::open(&dir, 0, opts.clone()).unwrap();
            for n in 1..=50u64 {
                wal.append(&write_payload(n));
            }
            wal.compact(&snapshot_payloads(1234)).unwrap();
            assert_eq!(wal.sealed_segments(), 0);
            // Fresh appends land after the snapshot frontier.
            assert_eq!(wal.append(&write_payload(51)), 51);
            wal.sync();
        }
        let (_wal, replay) = ShardWal::open(&dir, 0, opts).unwrap();
        // 4 snapshot records (all at seq 50) + 1 log record after.
        assert_eq!(replay.len(), 5);
        assert!(replay[..4].iter().all(|(seq, _)| *seq == 50));
        assert_eq!(replay[4].0, 51);
        assert_eq!(
            WalPayload::decode(&replay[3].1).unwrap(),
            WalPayload::SnapNextUid(1234)
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_snapshot_falls_back_to_older_one() {
        let dir = tmp_dir("snapfall");
        let opts = WalOptions::default();
        {
            let (wal, _) = ShardWal::open(&dir, 0, opts.clone()).unwrap();
            for n in 1..=5u64 {
                wal.append(&write_payload(n));
            }
            wal.compact(&snapshot_payloads(100)).unwrap();
            for n in 6..=9u64 {
                wal.append(&write_payload(n));
            }
            wal.compact(&snapshot_payloads(200)).unwrap();
        }
        // Corrupt the newest snapshot's tail: recovery must fall back to
        // the older one... which compaction deleted, so recreate a stale
        // copy first to exercise the fallback order.
        let newest = dir.join(segment::snap_name(9));
        assert!(newest.exists());
        let older = dir.join(segment::snap_name(5));
        let encoded: Vec<Vec<u8>> =
            snapshot_payloads(100).iter().map(|p| p.encode()).collect();
        write_snapshot(&dir, 0, 5, &encoded).unwrap();
        assert!(older.exists());
        let mut bytes = std::fs::read(&newest).unwrap();
        let n = bytes.len();
        bytes.truncate(n - 6);
        std::fs::write(&newest, &bytes).unwrap();

        let (_wal, replay) = ShardWal::open(&dir, 0, opts).unwrap();
        // Fallback snapshot at seq 5; no log records survive past it
        // (compaction deleted them), so replay is exactly the snapshot.
        assert_eq!(replay.len(), 4);
        assert!(replay.iter().all(|(seq, _)| *seq == 5));
        assert_eq!(
            WalPayload::decode(&replay[3].1).unwrap(),
            WalPayload::SnapNextUid(100)
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_from_streams_committed_prefix() {
        let dir = tmp_dir("readfrom");
        let (wal, _) = ShardWal::open(&dir, 0, WalOptions::default()).unwrap();
        for n in 1..=10u64 {
            wal.append(&write_payload(n));
        }
        wal.sync();
        let slice = wal.read_from(1, 4).unwrap();
        assert!(!slice.reset);
        assert_eq!(slice.tip, 10);
        assert_eq!(slice.next, 5);
        assert_eq!(slice.records.len(), 4);
        assert_eq!(slice.records[0].0, 1);
        let slice = wal.read_from(slice.next, 100).unwrap();
        assert_eq!(slice.records.len(), 6);
        assert_eq!(slice.next, 11);
        // Caught up: empty slice.
        let slice = wal.read_from(11, 100).unwrap();
        assert!(slice.records.is_empty());
        assert_eq!(slice.next, 11);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_from_behind_horizon_resets_with_snapshot() {
        let dir = tmp_dir("reset");
        let (wal, _) = ShardWal::open(&dir, 0, WalOptions::default()).unwrap();
        for n in 1..=8u64 {
            wal.append(&write_payload(n));
        }
        wal.compact(&snapshot_payloads(99)).unwrap();
        for n in 9..=12u64 {
            wal.append(&write_payload(n));
        }
        wal.sync();
        // A poller at seq 3 is behind the horizon (snapshot upto = 8).
        let slice = wal.read_from(3, 100).unwrap();
        assert!(slice.reset);
        assert_eq!(slice.next, 9);
        assert_eq!(slice.records.len(), 4); // the snapshot payloads
        assert!(slice.records.iter().all(|(seq, _)| *seq == 8));
        // Following the reset cursor streams the post-snapshot log.
        let slice = wal.read_from(slice.next, 100).unwrap();
        assert!(!slice.reset);
        assert_eq!(slice.records.len(), 4);
        assert_eq!(slice.records[0].0, 9);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn uncommitted_records_are_not_served() {
        // read_from sees only the committed prefix: records queued but
        // not yet fsynced (committer starved by a zero-length window
        // trick is racy, so just check tip gating directly).
        let dir = tmp_dir("gate");
        let (wal, _) = ShardWal::open(&dir, 0, WalOptions::default()).unwrap();
        for n in 1..=5u64 {
            wal.append(&write_payload(n));
        }
        wal.sync();
        let tip = wal.committed();
        let slice = wal.read_from(1, 100).unwrap();
        assert!(slice.records.iter().all(|(seq, _)| *seq <= tip));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
