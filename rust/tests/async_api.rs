//! Integration tests for the asynchronous ticket API: `flush()` as a
//! true barrier under adversarial fault schedules, ticket `wait()`
//! surfacing shard errors, fire-and-forget error delivery, and parity of
//! the compute/communicate-overlapped trainer with the synchronous path.

use std::time::Duration;

use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::eval::perplexity::holdout_perplexity;
use glint_lda::lda::sweep::SamplerParams;
use glint_lda::lda::trainer::{TrainConfig, Trainer};
use glint_lda::net::FaultPlan;
use glint_lda::ps::client::{BigMatrix, CoordDeltas, PsClient};
use glint_lda::ps::config::PsConfig;
use glint_lda::ps::server::ServerGroup;
use glint_lda::util::error::Error;
use glint_lda::util::rng::Pcg64;

/// Fire-and-forget pushes under a lossy, duplicating fault plan, then a
/// single `flush()` barrier: every delta must be applied exactly once
/// and be visible to the first pull after the barrier.
#[test]
fn flush_is_a_true_barrier_under_lossy_network() {
    let cfg = PsConfig {
        shards: 3,
        pipeline_depth: 8,
        timeout: Duration::from_millis(20),
        ..PsConfig::default()
    };
    let group = ServerGroup::start(cfg.clone(), FaultPlan::lossy(0.15, 0.1), 0x5eed);
    let client = PsClient::connect(&group.transport(), cfg);
    let m: BigMatrix<i64> = client.matrix(50, 2).unwrap();
    let mut rng = Pcg64::new(0xa57);
    let mut expect = vec![0i64; 50 * 2];
    for _ in 0..40 {
        let n = 1 + rng.below(30);
        let mut deltas = CoordDeltas::default();
        for _ in 0..n {
            let r = rng.below(50) as u64;
            let c = rng.below(2) as u32;
            let v = rng.below(5) as i64 - 2;
            deltas.rows.push(r);
            deltas.cols.push(c);
            deltas.values.push(v);
            expect[(r * 2 + c as u64) as usize] += v;
        }
        // Ticket dropped on purpose: fire-and-forget.
        let _ = m.push_coords_async(&deltas);
    }
    client.flush().unwrap();
    let all: Vec<u64> = (0..50).collect();
    let got = m.pull_rows(&all).unwrap();
    assert_eq!(got, expect, "counts must be exact right after the barrier");
}

fn dead_server_setup() -> (PsClient, BigMatrix<i64>) {
    let cfg = PsConfig {
        shards: 2,
        max_retries: 2,
        timeout: Duration::from_millis(5),
        ..PsConfig::default()
    };
    let group = ServerGroup::start(cfg.clone(), FaultPlan::reliable(), 3);
    let client = PsClient::connect(&group.transport(), cfg);
    let m: BigMatrix<i64> = client.matrix(8, 1).unwrap();
    // Kill the shards; subsequent operations exhaust their retry budget.
    group.shutdown();
    (client, m)
}

/// A shard failure reaches the caller through the ticket's `wait()`, as
/// a typed error — not a panic on some background thread.
#[test]
fn ticket_wait_surfaces_shard_errors() {
    let (_client, m) = dead_server_setup();
    match m.pull_rows_async(&[0, 5]).wait() {
        Err(Error::PsTimeout { attempts, .. }) => assert_eq!(attempts, 2),
        other => panic!("want PsTimeout through wait(), got {other:?}"),
    }
    let deltas = CoordDeltas { rows: vec![1], cols: vec![0], values: vec![1] };
    assert!(matches!(m.push_coords_async(&deltas).wait(), Err(Error::PsTimeout { .. })));
}

/// A fire-and-forget push whose shard has died must not vanish
/// silently: the next `flush()` reports it.
#[test]
fn flush_reports_orphaned_push_errors() {
    let (client, m) = dead_server_setup();
    let deltas = CoordDeltas { rows: vec![2], cols: vec![0], values: vec![3] };
    let _ = m.push_coords_async(&deltas); // dropped ticket
    match client.flush() {
        Err(Error::PsTimeout { .. }) => {}
        other => panic!("flush must surface the orphaned push error, got {other:?}"),
    }
    // The error sink is drained: a second flush is clean.
    client.flush().unwrap();
}

fn parity_corpus() -> glint_lda::corpus::dataset::Corpus {
    generate(&SynthConfig {
        num_docs: 360,
        vocab_size: 800,
        num_topics: 8,
        avg_doc_len: 45.0,
        seed: 929,
        ..Default::default()
    })
}

fn train_holdout_perplexity(pipeline_depth: usize) -> f64 {
    let corpus = parity_corpus();
    let (train, test) = corpus.split_holdout(5);
    let cfg = TrainConfig {
        num_topics: 10,
        iterations: 8,
        workers: 3,
        shards: 2,
        sampler: SamplerParams {
            block_words: 256,
            buffer_cap: 2000,
            dense_top_words: 50,
            pipeline_depth,
            ..Default::default()
        },
        ..Default::default()
    };
    let mut trainer = Trainer::new(cfg, &train).unwrap();
    let model = trainer.run(&train).unwrap();
    holdout_perplexity(&model, &test, 5, 7)
}

/// The overlapped trainer (deep prefetch + fire-and-forget flushes)
/// reaches the same held-out perplexity as the synchronous path
/// (`pipeline_depth = 0`) on the 2-shard sim deployment, within
/// sampling noise.
#[test]
fn overlapped_trainer_matches_synchronous_heldout_perplexity() {
    let sync = train_holdout_perplexity(0);
    let overlapped = train_holdout_perplexity(8);
    assert!(sync.is_finite() && overlapped.is_finite());
    let ratio = overlapped / sync;
    assert!(
        (0.9..1.1).contains(&ratio),
        "overlapped perplexity {overlapped:.1} diverged from synchronous {sync:.1} \
         (ratio {ratio:.3})"
    );
}
