//! TCP transport integration tests: the framing layer against real
//! sockets, the multi-process `serve` path driven in-process, recovery
//! from dropped connections through the exponential back-off retry, and
//! LightLDA training parity between the simulated and TCP transports.

use std::net::TcpListener;
use std::time::Duration;

use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::eval::perplexity::holdout_perplexity;
use glint_lda::lda::sweep::SamplerParams;
use glint_lda::lda::trainer::{TrainConfig, Trainer};
use glint_lda::net::frame::{read_tagged_frame, write_tagged_frame};
use glint_lda::net::tcp::TcpTransport;
use glint_lda::net::Transport;
use glint_lda::ps::client::{BigMatrix, CoordDeltas, PsClient};
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::messages::{Request, Response};
use glint_lda::ps::server::{ShardState, TcpShardServer};

fn loopback_addrs(n: usize) -> Vec<std::net::SocketAddr> {
    vec!["127.0.0.1:0".parse().unwrap(); n]
}

/// Full protocol over real sockets: create, exactly-once pushes, pulls,
/// shard introspection, shutdown — through `TcpShardServer`, the same
/// code path `glint-lda serve` runs.
#[test]
fn shard_server_roundtrip_over_tcp() {
    let cfg = PsConfig {
        shards: 2,
        timeout: Duration::from_millis(200),
        ..PsConfig::default()
    };
    let server = TcpShardServer::bind(cfg.clone(), 0, &loopback_addrs(2)).unwrap();
    let transport = TcpTransport::connect(server.addrs());
    let client = PsClient::connect(&transport, cfg);

    let m: BigMatrix<i64> = client.matrix(40, 3).unwrap();
    let deltas = CoordDeltas {
        rows: vec![0, 1, 39, 0],
        cols: vec![0, 2, 1, 0],
        values: vec![5, -2, 7, 3],
    };
    m.push_coords(&deltas).unwrap();
    let vals = m.pull_rows(&[0, 1, 39]).unwrap();
    assert_eq!(vals[0], 8); // 5 + 3 accumulated
    assert_eq!(vals[3 + 2], -2);
    assert_eq!(vals[6 + 1], 7);

    // No uid leaks, both shards hold rows, and the layout handshake
    // agrees with the servers.
    client.validate_deployment().unwrap();
    let infos = client.shard_infos().unwrap();
    assert_eq!(infos.len(), 2);
    assert_eq!(infos.iter().map(|i| i.pending_uids).sum::<u64>(), 0);
    assert_eq!(infos.iter().map(|i| i.local_rows).sum::<u64>(), 40);

    client.shutdown_servers().unwrap();
    server.join();
}

/// Two single-shard server "processes" (separately bound listeners with
/// disjoint shard ids) serving one client — the multi-machine topology,
/// on loopback.
#[test]
fn split_shard_servers_compose() {
    let cfg = PsConfig {
        shards: 2,
        timeout: Duration::from_millis(200),
        ..PsConfig::default()
    };
    let s0 = TcpShardServer::bind(cfg.clone(), 0, &loopback_addrs(1)).unwrap();
    let s1 = TcpShardServer::bind(cfg.clone(), 1, &loopback_addrs(1)).unwrap();
    let addrs = vec![s0.addrs()[0], s1.addrs()[0]];
    let transport = TcpTransport::connect(&addrs);
    let client = PsClient::connect(&transport, cfg);

    let m: BigMatrix<i64> = client.matrix(10, 1).unwrap();
    let deltas = CoordDeltas {
        rows: (0..10).collect(),
        cols: vec![0; 10],
        values: (0..10).map(|i| i as i64).collect(),
    };
    m.push_coords(&deltas).unwrap();
    let all: Vec<u64> = (0..10).collect();
    let got = m.pull_rows(&all).unwrap();
    assert_eq!(got, (0..10).map(|i| i as i64).collect::<Vec<_>>());

    // A client that connects only one of the two shards must be rejected
    // by the layout handshake instead of silently mis-partitioning rows.
    let bad_cfg = PsConfig {
        shards: 1,
        timeout: Duration::from_millis(200),
        ..PsConfig::default()
    };
    let bad_client = PsClient::connect(&TcpTransport::connect(&addrs[..1]), bad_cfg);
    assert!(bad_client.validate_deployment().is_err());

    client.shutdown_servers().unwrap();
    s0.join();
    s1.join();
}

/// A connection dropped mid-request (server reads the frame, then closes
/// without replying) must surface as a lost message and be recovered by
/// the existing exponential back-off retry on a fresh connection.
#[test]
fn dropped_connection_pull_recovers_via_retry() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = PsConfig {
        shards: 1,
        timeout: Duration::from_millis(50),
        max_retries: 8,
        ..PsConfig::default()
    };
    let server_cfg = cfg.clone();
    let server = std::thread::spawn(move || {
        let mut state = ShardState::new(0, server_cfg);
        // First connection: swallow one frame, then drop the socket
        // without replying — an at-most-once loss.
        let (mut doomed, _) = listener.accept().unwrap();
        let _ = read_tagged_frame(&mut doomed);
        drop(doomed);
        // After that, behave: serve decoded requests (echoing each
        // frame's correlation id) until Shutdown.
        loop {
            let (mut stream, _) = listener.accept().unwrap();
            while let Ok(Some((corr, payload))) = read_tagged_frame(&mut stream) {
                let req = Request::decode(&payload).unwrap();
                let stop = req == Request::Shutdown;
                let resp = if stop { Response::Ok } else { state.handle(req) };
                write_tagged_frame(&mut stream, corr, &resp.encode()).unwrap();
                if stop {
                    return;
                }
            }
        }
    });

    let transport = TcpTransport::connect(&[addr]);
    let client = PsClient::connect(&transport, cfg);
    // The first CreateMatrix lands on the doomed connection; the retry
    // must dial a fresh one and succeed.
    let m: BigMatrix<i64> = client.matrix(10, 2).unwrap();
    let vals = m.pull_rows(&[0, 9]).unwrap();
    assert_eq!(vals, vec![0; 4]);
    let stats = transport.stats();
    assert!(
        stats[0].timeouts() >= 1,
        "the dropped connection must be observed as a lost message"
    );
    client.shutdown_servers().unwrap();
    server.join().unwrap();
}

fn parity_corpus() -> glint_lda::corpus::dataset::Corpus {
    generate(&SynthConfig {
        num_docs: 360,
        vocab_size: 800,
        num_topics: 8,
        avg_doc_len: 45.0,
        seed: 424,
        ..Default::default()
    })
}

fn train_holdout_perplexity(transport: TransportMode) -> f64 {
    let corpus = parity_corpus();
    let (train, test) = corpus.split_holdout(5);
    let cfg = TrainConfig {
        num_topics: 10,
        iterations: 8,
        workers: 3,
        shards: 2,
        sampler: SamplerParams {
            block_words: 256,
            buffer_cap: 2000,
            dense_top_words: 50,
            ..Default::default()
        },
        transport,
        ..Default::default()
    };
    let mut trainer = Trainer::new(cfg, &train).unwrap();
    let model = trainer.run(&train).unwrap();
    holdout_perplexity(&model, &test, 5, 7)
}

/// The acceptance bar for the transport: LightLDA over TCP loopback
/// (2 shards on 127.0.0.1) reaches the same held-out perplexity as the
/// simulated transport, within sampling noise.
#[test]
fn tcp_training_matches_sim_heldout_perplexity() {
    let sim = train_holdout_perplexity(TransportMode::Sim);
    let tcp = train_holdout_perplexity(TransportMode::TcpLoopback);
    assert!(sim.is_finite() && tcp.is_finite());
    let ratio = tcp / sim;
    assert!(
        (0.9..1.1).contains(&ratio),
        "tcp perplexity {tcp:.1} diverged from sim {sim:.1} (ratio {ratio:.3})"
    );
}

/// Exactness over TCP: after training iterations, the server-side count
/// tables must equal the counts recomputed from worker assignments —
/// the same invariant the sim transport guarantees.
#[test]
fn tcp_training_counts_stay_consistent() {
    let corpus = parity_corpus();
    let cfg = TrainConfig {
        num_topics: 8,
        iterations: 2,
        workers: 3,
        shards: 3,
        sampler: SamplerParams {
            block_words: 128,
            buffer_cap: 1000,
            dense_top_words: 30,
            ..Default::default()
        },
        transport: TransportMode::TcpLoopback,
        ..Default::default()
    };
    let mut trainer = Trainer::new(cfg, &corpus).unwrap();
    trainer.run_iteration().unwrap();
    trainer.run_iteration().unwrap();
    trainer.verify_counts().unwrap();
    assert!(trainer.bytes_pushed() > 0);
    assert!(trainer.shard_request_counts().iter().all(|&c| c > 0));
}
