//! Integration tests for the cluster runtime: an in-process deployment
//! of 1 coordinator + N worker threads + TCP shard servers — the same
//! processes a real multi-machine run would use, minus the machines.

use std::net::SocketAddr;
use std::path::PathBuf;
use std::thread;
use std::time::Duration;

use glint_lda::cluster::{run_worker, ClusterOutcome, Coordinator, CorpusSpec, WorkerOptions};
use glint_lda::corpus::dataset::Corpus;
use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::eval::perplexity::holdout_perplexity;
use glint_lda::lda::checkpoint::PartitionCheckpoint;
use glint_lda::lda::sweep::SamplerParams;
use glint_lda::lda::trainer::{TrainConfig, Trainer};
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::server::TcpShardServer;

fn spawn_shards(n: usize) -> (TcpShardServer, Vec<String>) {
    let want: Vec<SocketAddr> = (0..n).map(|_| "127.0.0.1:0".parse().unwrap()).collect();
    let server = TcpShardServer::bind(PsConfig::with_shards(n), 0, &want).unwrap();
    let addrs = server.addrs().iter().map(|a| a.to_string()).collect();
    (server, addrs)
}

fn parity_corpus() -> Corpus {
    generate(&SynthConfig {
        num_docs: 360,
        vocab_size: 800,
        num_topics: 8,
        avg_doc_len: 45.0,
        seed: 424,
        ..Default::default()
    })
}

fn cluster_cfg(shard_addrs: Vec<String>) -> TrainConfig {
    TrainConfig {
        num_topics: 10,
        iterations: 8,
        workers: 2,
        shards: 2,
        sampler: SamplerParams {
            block_words: 256,
            buffer_cap: 2000,
            dense_top_words: 50,
            ..Default::default()
        },
        eval_every: 0,
        transport: TransportMode::Connect(shard_addrs),
        heartbeat_ms: 100,
        straggler_timeout_ms: 5000,
        max_staleness: 1,
        ..Default::default()
    }
}

/// Run a full cluster training: coordinator thread + `workers` worker
/// threads (each handed the corpus in-process), against 2 TCP shards.
fn run_cluster(
    cfg: TrainConfig,
    train: &Corpus,
    worker_opts: Vec<WorkerOptions>,
) -> ClusterOutcome {
    let coordinator =
        Coordinator::bind("127.0.0.1:0", cfg, train, CorpusSpec::Provided).unwrap();
    let addr = coordinator.addr().to_string();
    let coord = thread::spawn(move || coordinator.run().unwrap());
    let mut workers = Vec::new();
    for mut opts in worker_opts {
        opts.join = addr.clone();
        if opts.corpus.is_none() {
            opts.corpus = Some(train.clone());
        }
        workers.push(thread::spawn(move || run_worker(opts)));
        // Stagger spawns so partition assignment follows spawn order
        // (tests rely on which worker holds a partition vs stands by).
        thread::sleep(Duration::from_millis(150));
    }
    let outcome = coord.join().unwrap();
    for w in workers {
        // Workers either finish cleanly or (in kill tests) crashed on
        // purpose; both are Ok summaries.
        w.join().unwrap().unwrap();
    }
    outcome
}

/// Acceptance: a multi-process run (coordinator + 2 workers + 2 TCP
/// shards) reaches held-out perplexity within noise of the in-process
/// trainer on the same corpus and seed.
#[test]
fn cluster_matches_in_process_heldout_perplexity() {
    let corpus = parity_corpus();
    let (train, test) = corpus.split_holdout(5);

    // In-process reference: same partitioning (workers == 2), same
    // sampler, simulated transport.
    let mut single_cfg = cluster_cfg(Vec::new());
    single_cfg.transport = TransportMode::Sim;
    let mut trainer = Trainer::new(single_cfg, &train).unwrap();
    let single_model = trainer.run(&train).unwrap();
    let single = holdout_perplexity(&single_model, &test, 5, 7);

    let (_shards, addrs) = spawn_shards(2);
    let outcome = run_cluster(
        cluster_cfg(addrs),
        &train,
        vec![WorkerOptions::default(), WorkerOptions::default()],
    );
    let cluster = holdout_perplexity(&outcome.model, &test, 5, 7);

    assert!(single.is_finite() && cluster.is_finite());
    assert_eq!(outcome.epochs, 0, "no failures expected");
    let ratio = cluster / single;
    assert!(
        (0.9..1.1).contains(&ratio),
        "cluster perplexity {cluster:.1} diverged from in-process {single:.1} \
         (ratio {ratio:.3})"
    );
}

/// Acceptance: a worker killed mid-iteration is detected by heartbeat
/// silence, its partition is reassigned to a standby worker, the run
/// rolls onto a fresh count table rebuilt from per-partition
/// checkpoints, completes — and the final table exactly equals the
/// counts recomputed from the final checkpoints.
#[test]
fn worker_kill_recovers_via_partition_reassignment() {
    let corpus = parity_corpus();
    let (train, _test) = corpus.split_holdout(5);
    let dir = std::env::temp_dir()
        .join(format!("glint_cluster_kill_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let (_shards, addrs) = spawn_shards(2);
    let mut cfg = cluster_cfg(addrs);
    cfg.iterations = 6;
    cfg.checkpoint_dir = Some(PathBuf::from(&dir));
    cfg.heartbeat_ms = 100;
    // Long enough that a loaded CI box cannot spuriously reap a healthy
    // worker (15 missed heartbeats), short enough to keep the test fast.
    cfg.straggler_timeout_ms = 1500;
    let k = cfg.num_topics;

    let outcome = run_cluster(
        cfg,
        &train,
        vec![
            // Victim: vanishes right after sweeping iteration 2 —
            // pushes flushed, nothing reported, table contaminated.
            WorkerOptions { crash_at_iteration: Some(2), ..WorkerOptions::default() },
            // Healthy peer.
            WorkerOptions::default(),
            // Standby: parked with Wait until the victim's partition
            // frees up, then picks it up and rebuilds from checkpoint.
            WorkerOptions::default(),
        ],
    );

    assert!(outcome.epochs >= 1, "a failure must roll the epoch");
    assert!(outcome.reassignments >= 1, "the lost partition must be reassigned");

    // Rebuilt-count consistency: the final model on the (post-recovery)
    // parameter servers must exactly equal the counts recomputed from
    // the final per-partition checkpoints.
    let ranges = train.partitions(2);
    let kk = k as usize;
    let mut expect_wk = vec![0i64; train.vocab_size as usize * kk];
    let mut expect_k = vec![0i64; kk];
    for (p, range) in ranges.iter().enumerate() {
        let ckpt = PartitionCheckpoint::load_latest(&dir, p as u32)
            .unwrap()
            .expect("final checkpoint per partition");
        assert_eq!(ckpt.inner.iteration, 6, "partition {p} must finish all iterations");
        assert_eq!(ckpt.doc_start as usize, range.start);
        assert_eq!(ckpt.inner.assignments.len(), range.len());
        for (local, d) in range.clone().enumerate() {
            let doc = &train.docs[d];
            let z = &ckpt.inner.assignments[local];
            assert_eq!(z.len(), doc.tokens.len());
            for (pos, &w) in doc.tokens.iter().enumerate() {
                expect_wk[w as usize * kk + z[pos] as usize] += 1;
                expect_k[z[pos] as usize] += 1;
            }
        }
    }
    assert_eq!(
        expect_wk, outcome.model.n_wk,
        "final count table must equal the checkpointed assignments"
    );
    assert_eq!(expect_k, outcome.model.n_k);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The bounded-staleness knob at 0 forces lockstep and still completes;
/// the report covers every iteration exactly once.
#[test]
fn lockstep_staleness_zero_completes_with_full_report() {
    let corpus = generate(&SynthConfig {
        num_docs: 150,
        vocab_size: 400,
        num_topics: 5,
        avg_doc_len: 30.0,
        seed: 33,
        ..Default::default()
    });
    let (_shards, addrs) = spawn_shards(2);
    let mut cfg = cluster_cfg(addrs);
    cfg.iterations = 4;
    cfg.max_staleness = 0;
    cfg.eval_every = 2;
    let outcome = run_cluster(
        cfg,
        &corpus,
        vec![WorkerOptions::default(), WorkerOptions::default()],
    );
    let rows = outcome.report.rows();
    assert_eq!(rows.len(), 4, "one aggregate row per iteration");
    let iters: Vec<f64> = rows.iter().map(|r| r.get("iter").unwrap()).collect();
    assert_eq!(iters, vec![1.0, 2.0, 3.0, 4.0]);
    // Evaluation points carry an aggregated perplexity; PS health rides
    // every completed row.
    assert!(rows[1].get("perplexity").is_some());
    assert!(rows[3].get("perplexity").is_some());
    assert!(rows[0].get("perplexity").is_none());
    assert!(rows.iter().all(|r| r.get("ps_resident_bytes").is_some()));
    assert!(outcome.final_perplexity.is_some());
}

/// A late worker joining a fully staffed cluster parks as a standby
/// (Wait) and exits cleanly at Done without ever holding a partition.
#[test]
fn standby_worker_exits_cleanly_when_never_needed() {
    let corpus = generate(&SynthConfig {
        num_docs: 100,
        vocab_size: 300,
        num_topics: 4,
        avg_doc_len: 25.0,
        seed: 7,
        ..Default::default()
    });
    let (_shards, addrs) = spawn_shards(2);
    let mut cfg = cluster_cfg(addrs);
    cfg.iterations = 3;
    let coordinator =
        Coordinator::bind("127.0.0.1:0", cfg, &corpus, CorpusSpec::Provided).unwrap();
    let addr = coordinator.addr().to_string();
    let coord = thread::spawn(move || coordinator.run().unwrap());
    let mut handles = Vec::new();
    for _ in 0..2 {
        let opts = WorkerOptions {
            join: addr.clone(),
            corpus: Some(corpus.clone()),
            ..WorkerOptions::default()
        };
        handles.push(thread::spawn(move || run_worker(opts)));
    }
    // The standby joins slightly later so the two real workers hold the
    // partitions.
    thread::sleep(Duration::from_millis(200));
    let standby_opts = WorkerOptions {
        join: addr.clone(),
        corpus: Some(corpus.clone()),
        ..WorkerOptions::default()
    };
    let standby = thread::spawn(move || run_worker(standby_opts));
    let outcome = coord.join().unwrap();
    for h in handles {
        let summary = h.join().unwrap().unwrap();
        assert!(summary.sweeps >= 3);
    }
    let standby_summary = standby.join().unwrap().unwrap();
    assert_eq!(standby_summary.sweeps, 0);
    assert!(!standby_summary.crashed);
    assert_eq!(outcome.reassignments, 0);
}
