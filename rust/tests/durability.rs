//! Replication acceptance: a 2-shard deployment with one backup
//! replica per shard over real TCP. A primary dies mid-stream; the
//! client's route fails over to the backup, the backup is promoted,
//! and the stream continues. The promoted replica must hold exactly
//! the counts a no-fault run would have produced — every push uid
//! applied exactly once, including uids redelivered across the
//! failover — because the backup applied the primary's committed
//! WAL records (counts *and* dedup window) before the crash.

use std::net::SocketAddr;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use glint_lda::net::tcp::TcpTransport;
use glint_lda::ps::client::PsClient;
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::messages::{Data, Layout, Request, Response};
use glint_lda::ps::server::{TcpShardServer, ROLE_BACKUP, ROLE_PROMOTED};

const ROWS: u64 = 16; // global rows; 8 local per shard under cyclic
const COLS: u32 = 4;
const LOCAL: u64 = 4; // local rows the test actually touches

fn tmp(tag: &str) -> PathBuf {
    let name = format!("glint-durability-{tag}-{}", std::process::id());
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A client whose routes cover `addrs` as primaries (with optional
/// per-shard backups behind them).
fn client(addrs: &[SocketAddr], backups: &[SocketAddr]) -> PsClient {
    let cfg = PsConfig {
        shards: addrs.len(),
        transport: TransportMode::Connect(addrs.iter().map(|a| a.to_string()).collect()),
        backups: backups.iter().map(|a| a.to_string()).collect(),
        ..PsConfig::default()
    };
    let transport = TcpTransport::connect(addrs);
    PsClient::connect(&transport, cfg)
}

fn push(c: &PsClient, shard: usize, id: u32, uid: u64, row: u64, col: u32, val: i64) -> bool {
    match c
        .request_retry(
            shard,
            &Request::PushCoords {
                id,
                uid,
                rows: vec![row],
                cols: vec![col],
                values: Data::I64(vec![val]),
            },
        )
        .expect("push")
    {
        Response::PushAck { fresh } => fresh,
        other => panic!("unexpected push reply {other:?}"),
    }
}

/// Pull the test's local rows from one shard, row-major.
fn pull(c: &PsClient, shard: usize, id: u32) -> Vec<i64> {
    let req = Request::PullRows { id, rows: (0..LOCAL).collect() };
    match c.request_retry(shard, &req).expect("pull") {
        Response::Rows(Data::I64(v)) => v,
        other => panic!("unexpected pull reply {other:?}"),
    }
}

/// Shard-tagged push uid (the convention `GenUid` uses).
fn uid(shard: usize, n: u64) -> u64 {
    ((shard as u64) << 48) | n
}

#[test]
fn primary_death_fails_over_and_converges_exactly_once() {
    let wal_dir = tmp("wal");

    // Two primary processes, one WAL-backed shard each.
    let pcfg = PsConfig { wal_dir: Some(wal_dir.clone()), ..PsConfig::with_shards(2) };
    let want: Vec<SocketAddr> = vec!["127.0.0.1:0".parse().unwrap()];
    let primary0 = TcpShardServer::bind(pcfg.clone(), 0, &want).expect("bind primary 0");
    let primary1 = TcpShardServer::bind(pcfg.clone(), 1, &want).expect("bind primary 1");
    let p_addrs = vec![primary0.addrs()[0], primary1.addrs()[0]];

    // One backup process hosting a replica of each shard, tailing the
    // primaries' logs.
    let bcfg = PsConfig {
        backup_of: Some(p_addrs.iter().map(|a| a.to_string()).collect()),
        ..PsConfig::with_shards(2)
    };
    let b_want: Vec<SocketAddr> =
        vec!["127.0.0.1:0".parse().unwrap(), "127.0.0.1:0".parse().unwrap()];
    let backup = TcpShardServer::bind(bcfg, 0, &b_want).expect("bind backups");
    let b_addrs = backup.addrs().to_vec();

    let c = client(&p_addrs, &b_addrs);
    let id = c
        .matrix_with_layout::<i64>(ROWS, COLS, Layout::Dense)
        .expect("create matrix")
        .id();

    // Phase A: a deterministic push stream to both shards. `grid` is
    // what a no-fault run produces — the parity baseline.
    let mut grid = vec![vec![0i64; (LOCAL * COLS as u64) as usize]; 2];
    for s in 0..2 {
        for n in 1..=30u64 {
            let (row, col, val) = (n % LOCAL, (n % COLS as u64) as u32, (n % 5 + 1) as i64);
            assert!(push(&c, s, id, uid(s, n), row, col, val), "phase A uid must be fresh");
            grid[s][(row * COLS as u64 + col as u64) as usize] += val;
        }
    }

    // Let both replicas drain the primaries' committed logs, so the
    // upcoming crash loses nothing. (A lagging replica is healed by the
    // coordinator's epoch roll in training; this test isolates the
    // replication path itself.)
    let admin = client(&b_addrs, &[]);
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let caught_up = (0..2).all(|s| {
            let info = admin.shard_info(s).expect("backup info");
            info.role == ROLE_BACKUP && info.repl_applied > 0 && info.repl_lag == 0
        });
        if caught_up {
            break;
        }
        assert!(Instant::now() < deadline, "replicas never caught up");
        std::thread::sleep(Duration::from_millis(25));
    }

    // Kill primary 0 (the moral equivalent of kill -9: the process is
    // gone; only its committed WAL — already replicated — survives).
    let killer = client(&p_addrs[..1], &[]);
    killer.shutdown_servers().expect("stop primary 0");
    primary0.join();

    // The route discovers the death and fails over to the un-promoted
    // backup, which still answers introspection.
    let info = c.shard_info(0).expect("failover shard info");
    assert_eq!(info.role, ROLE_BACKUP, "route must fail over to the backup");
    c.promote_backup(0).expect("promote");
    assert_eq!(c.shard_info(0).expect("promoted info").role, ROLE_PROMOTED);

    // Redeliver every phase-A uid for the failed shard, as a client
    // retrying in-flight pushes after failover would. The replica's
    // replicated dedup window must reject each one.
    for n in 1..=30u64 {
        let (row, col, val) = (n % LOCAL, (n % COLS as u64) as u32, (n % 5 + 1) as i64);
        assert!(
            !push(&c, 0, id, uid(0, n), row, col, val),
            "uid {n} redelivered across failover must dedup"
        );
    }

    // The stream continues against the promoted replica.
    for n in 31..=40u64 {
        let (row, col, val) = (n % LOCAL, (n % COLS as u64) as u32, (n % 5 + 1) as i64);
        assert!(push(&c, 0, id, uid(0, n), row, col, val), "post-promotion uid must be fresh");
        grid[0][(row * COLS as u64 + col as u64) as usize] += val;
    }
    for n in 31..=40u64 {
        let (row, col, val) = (n % LOCAL, (n % COLS as u64) as u32, (n % 5 + 1) as i64);
        assert!(push(&c, 1, id, uid(1, n), row, col, val));
        grid[1][(row * COLS as u64 + col as u64) as usize] += val;
    }

    // Parity: both shards hold exactly the no-fault counts.
    assert_eq!(pull(&c, 0, id), grid[0], "promoted replica diverged from no-fault counts");
    assert_eq!(pull(&c, 1, id), grid[1], "surviving primary diverged");

    // The surviving primary logged the whole stream.
    let info1 = c.shard_info(1).expect("primary 1 info");
    assert!(info1.wal_records > 0 && info1.wal_commit_batches > 0);

    // Teardown: the main client reaches the promoted backup 0 and
    // primary 1; backup 1 needs a direct word.
    c.shutdown_servers().expect("stop survivors");
    let killer = client(&b_addrs[1..], &[]);
    killer.shutdown_servers().expect("stop backup 1");
    primary1.join();
    backup.join();
    let _ = std::fs::remove_dir_all(&wal_dir);
}
