//! Replication acceptance over real TCP.
//!
//! One test runs a 2-shard deployment with one backup replica per
//! shard: a primary dies mid-stream; the client's route fails over to
//! the backup, the backup is promoted, and the stream continues with
//! every push uid applied exactly once.
//!
//! The chain test runs a depth-2 standby chain behind one shard and
//! kills the primary AND the promoted first tier in sequence: each
//! promotion walks the chain head-ward, the surviving tail is
//! re-seeded (`ReplSeed`) behind the new head so redundancy returns
//! mid-run, and after the second kill the twice-promoted tail must
//! still hold counts bit-exact with a no-fault baseline — including
//! the dedup window, proved by redelivering every uid across both
//! failovers.

use std::net::SocketAddr;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use glint_lda::net::tcp::TcpTransport;
use glint_lda::ps::client::PsClient;
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::messages::{Data, Layout, Request, Response};
use glint_lda::ps::server::{TcpShardServer, ROLE_BACKUP, ROLE_PROMOTED};

const ROWS: u64 = 16; // global rows; 8 local per shard under cyclic
const COLS: u32 = 4;
const LOCAL: u64 = 4; // local rows the test actually touches

fn tmp(tag: &str) -> PathBuf {
    let name = format!("glint-durability-{tag}-{}", std::process::id());
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A client whose routes cover `addrs` as primaries (with optional
/// per-shard backups behind them).
fn client(addrs: &[SocketAddr], backups: &[SocketAddr]) -> PsClient {
    let cfg = PsConfig {
        shards: addrs.len(),
        transport: TransportMode::Connect(addrs.iter().map(|a| a.to_string()).collect()),
        backups: backups.iter().map(|a| a.to_string()).collect(),
        ..PsConfig::default()
    };
    let transport = TcpTransport::connect(addrs);
    PsClient::connect(&transport, cfg)
}

fn push(c: &PsClient, shard: usize, id: u32, uid: u64, row: u64, col: u32, val: i64) -> bool {
    match c
        .request_retry(
            shard,
            &Request::PushCoords {
                id,
                uid,
                rows: vec![row],
                cols: vec![col],
                values: Data::I64(vec![val]),
            },
        )
        .expect("push")
    {
        Response::PushAck { fresh } => fresh,
        other => panic!("unexpected push reply {other:?}"),
    }
}

/// Pull the test's local rows from one shard, row-major.
fn pull(c: &PsClient, shard: usize, id: u32) -> Vec<i64> {
    let req = Request::PullRows { id, rows: (0..LOCAL).collect() };
    match c.request_retry(shard, &req).expect("pull") {
        Response::Rows(Data::I64(v)) => v,
        other => panic!("unexpected pull reply {other:?}"),
    }
}

/// Shard-tagged push uid (the convention `GenUid` uses).
fn uid(shard: usize, n: u64) -> u64 {
    ((shard as u64) << 48) | n
}

/// The deterministic push for step `n`: coordinates plus value.
fn coords(n: u64) -> (u64, u32, i64) {
    (n % LOCAL, (n % COLS as u64) as u32, (n % 5 + 1) as i64)
}

/// Wait until the backup behind `admin` reports `repl_applied >= floor`
/// with zero lag — i.e. its applied tip covers the head's whole commit
/// window, so a kill right now loses nothing.
fn await_caught_up(admin: &PsClient, shard: usize, floor: u64, what: &str) -> u64 {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let info = admin.shard_info(shard).expect("replica info");
        if info.role == ROLE_BACKUP && info.repl_applied >= floor && info.repl_lag == 0 {
            return info.repl_applied;
        }
        assert!(
            Instant::now() < deadline,
            "{what} never caught up (applied {} / floor {floor}, lag {})",
            info.repl_applied,
            info.repl_lag
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

#[test]
fn primary_death_fails_over_and_converges_exactly_once() {
    let wal_dir = tmp("wal");

    // Two primary processes, one WAL-backed shard each.
    let pcfg = PsConfig { wal_dir: Some(wal_dir.clone()), ..PsConfig::with_shards(2) };
    let want: Vec<SocketAddr> = vec!["127.0.0.1:0".parse().unwrap()];
    let primary0 = TcpShardServer::bind(pcfg.clone(), 0, &want).expect("bind primary 0");
    let primary1 = TcpShardServer::bind(pcfg.clone(), 1, &want).expect("bind primary 1");
    let p_addrs = vec![primary0.addrs()[0], primary1.addrs()[0]];

    // One backup process hosting a replica of each shard, tailing the
    // primaries' logs.
    let bcfg = PsConfig {
        backup_of: Some(p_addrs.iter().map(|a| a.to_string()).collect()),
        ..PsConfig::with_shards(2)
    };
    let b_want: Vec<SocketAddr> =
        vec!["127.0.0.1:0".parse().unwrap(), "127.0.0.1:0".parse().unwrap()];
    let backup = TcpShardServer::bind(bcfg, 0, &b_want).expect("bind backups");
    let b_addrs = backup.addrs().to_vec();

    let c = client(&p_addrs, &b_addrs);
    let id = c
        .matrix_with_layout::<i64>(ROWS, COLS, Layout::Dense)
        .expect("create matrix")
        .id();

    // Phase A: a deterministic push stream to both shards. `grid` is
    // what a no-fault run produces — the parity baseline.
    let mut grid = vec![vec![0i64; (LOCAL * COLS as u64) as usize]; 2];
    for s in 0..2 {
        for n in 1..=30u64 {
            let (row, col, val) = (n % LOCAL, (n % COLS as u64) as u32, (n % 5 + 1) as i64);
            assert!(push(&c, s, id, uid(s, n), row, col, val), "phase A uid must be fresh");
            grid[s][(row * COLS as u64 + col as u64) as usize] += val;
        }
    }

    // Let both replicas drain the primaries' committed logs, so the
    // upcoming crash loses nothing. (A lagging replica is healed by the
    // coordinator's epoch roll in training; this test isolates the
    // replication path itself.)
    let admin = client(&b_addrs, &[]);
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let caught_up = (0..2).all(|s| {
            let info = admin.shard_info(s).expect("backup info");
            info.role == ROLE_BACKUP && info.repl_applied > 0 && info.repl_lag == 0
        });
        if caught_up {
            break;
        }
        assert!(Instant::now() < deadline, "replicas never caught up");
        std::thread::sleep(Duration::from_millis(25));
    }

    // Kill primary 0 (the moral equivalent of kill -9: the process is
    // gone; only its committed WAL — already replicated — survives).
    let killer = client(&p_addrs[..1], &[]);
    killer.shutdown_servers().expect("stop primary 0");
    primary0.join();

    // The route discovers the death and fails over to the un-promoted
    // backup, which still answers introspection.
    let info = c.shard_info(0).expect("failover shard info");
    assert_eq!(info.role, ROLE_BACKUP, "route must fail over to the backup");
    c.promote_backup(0).expect("promote");
    assert_eq!(c.shard_info(0).expect("promoted info").role, ROLE_PROMOTED);

    // Redeliver every phase-A uid for the failed shard, as a client
    // retrying in-flight pushes after failover would. The replica's
    // replicated dedup window must reject each one.
    for n in 1..=30u64 {
        let (row, col, val) = (n % LOCAL, (n % COLS as u64) as u32, (n % 5 + 1) as i64);
        assert!(
            !push(&c, 0, id, uid(0, n), row, col, val),
            "uid {n} redelivered across failover must dedup"
        );
    }

    // The stream continues against the promoted replica.
    for n in 31..=40u64 {
        let (row, col, val) = (n % LOCAL, (n % COLS as u64) as u32, (n % 5 + 1) as i64);
        assert!(push(&c, 0, id, uid(0, n), row, col, val), "post-promotion uid must be fresh");
        grid[0][(row * COLS as u64 + col as u64) as usize] += val;
    }
    for n in 31..=40u64 {
        let (row, col, val) = (n % LOCAL, (n % COLS as u64) as u32, (n % 5 + 1) as i64);
        assert!(push(&c, 1, id, uid(1, n), row, col, val));
        grid[1][(row * COLS as u64 + col as u64) as usize] += val;
    }

    // Parity: both shards hold exactly the no-fault counts.
    assert_eq!(pull(&c, 0, id), grid[0], "promoted replica diverged from no-fault counts");
    assert_eq!(pull(&c, 1, id), grid[1], "surviving primary diverged");

    // The surviving primary logged the whole stream.
    let info1 = c.shard_info(1).expect("primary 1 info");
    assert!(info1.wal_records > 0 && info1.wal_commit_batches > 0);

    // Teardown: the main client reaches the promoted backup 0 and
    // primary 1; backup 1 needs a direct word.
    c.shutdown_servers().expect("stop survivors");
    let killer = client(&b_addrs[1..], &[]);
    killer.shutdown_servers().expect("stop backup 1");
    primary1.join();
    backup.join();
    let _ = std::fs::remove_dir_all(&wal_dir);
}

#[test]
fn chain_of_two_survives_sequential_kills() {
    let p_wal = tmp("chain-p");
    let b1_wal = tmp("chain-b1");
    let b2_wal = tmp("chain-b2");

    // One WAL-backed primary shard...
    let pcfg = PsConfig { wal_dir: Some(p_wal.clone()), ..PsConfig::with_shards(1) };
    let want: Vec<SocketAddr> = vec!["127.0.0.1:0".parse().unwrap()];
    let primary = TcpShardServer::bind(pcfg, 0, &want).expect("bind primary");
    let p_addr = primary.addrs()[0];

    // ...and a chain of two standby tiers behind it, each a separate
    // process-equivalent tailing the serving head. Tier order is
    // promotion order; each tier carries its own wal dir so that, once
    // promoted, it can snapshot and feed the tier behind it.
    let tier = |wal: &PathBuf| PsConfig {
        wal_dir: Some(wal.clone()),
        backup_of: Some(vec![p_addr.to_string()]),
        ..PsConfig::with_shards(1)
    };
    let b1 = TcpShardServer::bind(tier(&b1_wal), 0, &want).expect("bind tier 1");
    let b2 = TcpShardServer::bind(tier(&b2_wal), 0, &want).expect("bind tier 2");
    let (b1_addr, b2_addr) = (b1.addrs()[0], b2.addrs()[0]);

    let c = client(&[p_addr], &[b1_addr, b2_addr]);
    let id = c
        .matrix_with_layout::<i64>(ROWS, COLS, Layout::Dense)
        .expect("create matrix")
        .id();

    // Phase A onto the primary; `grid` is the no-fault baseline the
    // twice-promoted survivor must match bit-exactly at the end.
    let mut grid = vec![0i64; (LOCAL * COLS as u64) as usize];
    for n in 1..=30u64 {
        let (row, col, val) = coords(n);
        assert!(push(&c, 0, id, uid(0, n), row, col, val), "phase A uid must be fresh");
        grid[(row * COLS as u64 + col as u64) as usize] += val;
    }

    // Both tiers drain the primary's committed log: CreateMatrix plus
    // 30 fresh pushes = 31 WAL records.
    let admin1 = client(&[b1_addr], &[]);
    let admin2 = client(&[b2_addr], &[]);
    await_caught_up(&admin1, 0, 31, "tier 1");
    await_caught_up(&admin2, 0, 31, "tier 2");

    // Kill 1: the primary dies. Promotion walks the chain head-ward
    // and lands on tier 1 (route position 1).
    client(&[p_addr], &[]).shutdown_servers().expect("stop primary");
    primary.join();
    assert_eq!(c.shard_info(0).expect("failover info").role, ROLE_BACKUP);
    let head = c.promote_backup(0).expect("first promotion");
    assert_eq!(head, 1, "promotion must land on the first live tier");
    assert_eq!(c.shard_info(0).expect("promoted info").role, ROLE_PROMOTED);

    // Re-seed the surviving tail behind the new head, as the
    // coordinator's probe loop does: tier 2 drops its dead-upstream
    // cursor, installs the head's promotion snapshot, and tails the
    // head under the bumped replication generation.
    let roles = c.replica_roles(0);
    assert_eq!(roles[1], Some(ROLE_PROMOTED), "route must see the promoted head");
    assert_eq!(roles[2], Some(ROLE_BACKUP), "tail tier must have survived");
    c.reseed_backup(0, 2, &b1_addr.to_string()).expect("re-seed tier 2");
    let seeded_at = await_caught_up(&admin2, 0, 31, "freshly seeded tier 2");

    // Redelivered phase-A uids must hit the replicated dedup window.
    for n in 1..=30u64 {
        let (row, col, val) = coords(n);
        assert!(
            !push(&c, 0, id, uid(0, n), row, col, val),
            "uid {n} redelivered across failover must dedup"
        );
    }

    // Phase B continues on the promoted head while the tail tier tails
    // it; wait until the tail holds all 10 new records (redelivered
    // dedup'd pushes are never logged, so the frontier is exact) —
    // bounded repl_lag, zero at the sample point.
    for n in 31..=40u64 {
        let (row, col, val) = coords(n);
        assert!(push(&c, 0, id, uid(0, n), row, col, val), "phase B uid must be fresh");
        grid[(row * COLS as u64 + col as u64) as usize] += val;
    }
    await_caught_up(&admin2, 0, seeded_at + 10, "tier 2 behind the new head");

    // Kill 2: the promoted head dies too. The route walks one tier
    // deeper and the re-seeded tail takes over.
    client(&[b1_addr], &[]).shutdown_servers().expect("stop tier 1");
    b1.join();
    assert_eq!(c.shard_info(0).expect("second failover info").role, ROLE_BACKUP);
    let head = c.promote_backup(0).expect("second promotion");
    assert_eq!(head, 2, "second promotion must land on the tail tier");
    assert_eq!(c.shard_info(0).expect("tail info").role, ROLE_PROMOTED);

    // Redelivery across the second failover: phase-B uids dedup, which
    // proves the re-seed carried the dedup window, not just counts.
    for n in 31..=40u64 {
        let (row, col, val) = coords(n);
        assert!(
            !push(&c, 0, id, uid(0, n), row, col, val),
            "uid {n} redelivered across the second failover must dedup"
        );
    }
    // Phase C lands on the twice-promoted tail.
    for n in 41..=50u64 {
        let (row, col, val) = coords(n);
        assert!(push(&c, 0, id, uid(0, n), row, col, val), "phase C uid must be fresh");
        grid[(row * COLS as u64 + col as u64) as usize] += val;
    }

    // Bit-exact parity with the no-fault baseline across two kills and
    // one mid-run re-seed.
    assert_eq!(pull(&c, 0, id), grid, "chain survivor diverged from no-fault counts");

    c.shutdown_servers().expect("stop tail");
    b2.join();
    for d in [p_wal, b1_wal, b2_wal] {
        let _ = std::fs::remove_dir_all(&d);
    }
}
