//! WAL recovery, end-to-end through a WAL-backed TCP shard server:
//! torn tails lose only the records past the tear, corrupt segments
//! stop replay at the gap instead of corrupting counts (mirroring
//! `Checkpoint::load_latest`'s skip-to-newest-valid semantics), and a
//! randomized exactly-once property — replaying the log through the
//! dedup window reproduces the shard's counts exactly, with every push
//! uid applied at most once per forget-cycle.

use std::net::SocketAddr;
use std::path::{Path, PathBuf};

use glint_lda::net::tcp::TcpTransport;
use glint_lda::ps::client::PsClient;
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::messages::{Data, Layout, Request, Response};
use glint_lda::ps::server::TcpShardServer;
use glint_lda::util::proptest::forall_explain;

fn tmp(tag: &str) -> PathBuf {
    let name = format!("glint-wal-recovery-{tag}-{}", std::process::id());
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn serve(cfg: &PsConfig) -> TcpShardServer {
    let want: Vec<SocketAddr> = vec!["127.0.0.1:0".parse().unwrap()];
    TcpShardServer::bind(cfg.clone(), 0, &want).expect("bind shard")
}

fn client_for(server: &TcpShardServer) -> PsClient {
    let addrs: Vec<String> = server.addrs().iter().map(|a| a.to_string()).collect();
    let cfg = PsConfig {
        shards: 1,
        transport: TransportMode::Connect(addrs),
        ..PsConfig::default()
    };
    let transport = TcpTransport::connect(server.addrs());
    PsClient::connect(&transport, cfg)
}

/// Stop the hosted shard and wait the server out, flushing its WAL.
fn stop(server: TcpShardServer, client: &PsClient) {
    client.shutdown_servers().expect("shutdown");
    server.join();
}

fn push(client: &PsClient, id: u32, uid: u64, row: u64, col: u32, val: i64) -> bool {
    match client
        .request_retry(
            0,
            &Request::PushCoords {
                id,
                uid,
                rows: vec![row],
                cols: vec![col],
                values: Data::I64(vec![val]),
            },
        )
        .expect("push")
    {
        Response::PushAck { fresh } => fresh,
        other => panic!("unexpected push reply {other:?}"),
    }
}

/// The shard's log segment files in base-sequence order.
fn log_files(shard_dir: &Path) -> Vec<PathBuf> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(shard_dir)
        .expect("wal dir")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("log-"))
        })
        .collect();
    files.sort();
    files
}

#[test]
fn torn_tail_loses_only_the_records_past_the_tear() {
    let dir = tmp("torn");
    let cfg = PsConfig { wal_dir: Some(dir.clone()), ..PsConfig::with_shards(1) };

    let server = serve(&cfg);
    let client = client_for(&server);
    let m = client.matrix_with_layout::<i64>(8, 4, Layout::Dense).unwrap();
    let id = m.id();
    assert!(push(&client, id, 101, 0, 0, 5));
    assert!(push(&client, id, 102, 1, 1, 7));
    stop(server, &client);

    // Tear one byte off the newest log segment: the last record's
    // checksum no longer matches, so recovery must replay everything
    // before it and nothing after.
    let files = log_files(&dir.join("shard-0000"));
    let newest = files.last().expect("a log segment");
    let mut bytes = std::fs::read(newest).unwrap();
    bytes.pop();
    std::fs::write(newest, &bytes).unwrap();

    let server = serve(&cfg);
    let client = client_for(&server);
    let m = client.attach_matrix::<i64>(id, 8, 4, Layout::Dense).unwrap();
    let rows = m.pull_rows(&[0, 1]).unwrap();
    assert_eq!(&rows[..4], &[5, 0, 0, 0], "pre-tear record must replay");
    assert_eq!(&rows[4..], &[0, 0, 0, 0], "torn record must not replay");
    // The torn push's dedup record is gone with it, so redelivery
    // applies; the surviving push's dedup record replayed, so its
    // redelivery dedups.
    assert!(push(&client, id, 102, 1, 1, 7), "redelivery past the tear is fresh");
    assert!(!push(&client, id, 101, 0, 0, 5), "replayed uid must dedup");
    assert_eq!(m.pull_rows(&[1]).unwrap(), vec![0, 7, 0, 0]);
    stop(server, &client);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_segment_stops_replay_at_the_gap() {
    let dir = tmp("corrupt");
    // Tiny segments so the log spreads across many files; compaction
    // disabled so every record stays in its log segment (a snapshot
    // would mask the corruption this test injects).
    let cfg = PsConfig {
        wal_dir: Some(dir.clone()),
        wal_segment_bytes: 256,
        wal_compact_after: usize::MAX,
        ..PsConfig::with_shards(1)
    };

    const N: u64 = 40;
    let server = serve(&cfg);
    let client = client_for(&server);
    let m = client.matrix_with_layout::<i64>(N, 1, Layout::Dense).unwrap();
    let id = m.id();
    // Row i gets +1 under the i-th logged push, so the recovered state
    // directly encodes which log prefix replayed.
    for i in 0..N {
        assert!(push(&client, id, 1000 + i, i, 0, 1));
    }
    stop(server, &client);

    let shard_dir = dir.join("shard-0000");
    let files = log_files(&shard_dir);
    assert!(files.len() >= 4, "expected several sealed segments, got {files:?}");
    // Flip a byte in the middle of the third segment: its scan stops at
    // the corrupt record, later segments no longer chain, and replay
    // must stop at the gap rather than apply post-gap mutations.
    let victim = &files[2];
    let mut bytes = std::fs::read(victim).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xff;
    std::fs::write(victim, &bytes).unwrap();

    let server = serve(&cfg);
    let client = client_for(&server);
    let m = client.attach_matrix::<i64>(id, N, 1, Layout::Dense).unwrap();
    let rows: Vec<u64> = (0..N).collect();
    let values = m.pull_rows(&rows).unwrap();
    let k = values.iter().take_while(|&&v| v == 1).count();
    assert!(
        values[k..].iter().all(|&v| v == 0),
        "replay must be an exact log prefix, got {values:?}"
    );
    assert!(k >= 1, "the first (intact) segment must replay");
    assert!(
        (k as u64) < N,
        "the corrupt segment must cost at least its own tail"
    );
    stop(server, &client);
    let _ = std::fs::remove_dir_all(&dir);
}

/// One step of the randomized script.
#[derive(Debug)]
enum Op {
    /// Deliver a push under `uid` (uids repeat, modelling retries).
    Push { uid: u64, row: u64, col: u32, val: i64 },
    /// Release `uid`'s dedup record (a later redelivery re-applies).
    Forget { uid: u64 },
}

#[test]
fn replaying_the_log_through_the_dedup_window_is_exactly_once() {
    const ROWS: u64 = 6;
    const COLS: u32 = 4;
    let mut case = 0u32;
    forall_explain(
        "wal replay reproduces exact shard counts",
        6,
        |rng| {
            let len = 8 + rng.below(24);
            (0..len)
                .map(|_| {
                    let uid = 1 + rng.below(8) as u64;
                    if rng.bernoulli(0.2) {
                        Op::Forget { uid }
                    } else {
                        Op::Push {
                            uid,
                            row: rng.below(ROWS as usize) as u64,
                            col: rng.below(COLS as usize) as u32,
                            val: 1 + rng.below(50) as i64,
                        }
                    }
                })
                .collect::<Vec<Op>>()
        },
        |script| {
            case += 1;
            let dir = tmp(&format!("prop-{case}"));
            let cfg = PsConfig { wal_dir: Some(dir.clone()), ..PsConfig::with_shards(1) };

            let server = serve(&cfg);
            let client = client_for(&server);
            let m = client
                .matrix_with_layout::<i64>(ROWS, COLS, Layout::Dense)
                .map_err(|e| e.to_string())?;
            let id = m.id();

            // Reference: a uid applies exactly once while its dedup
            // record lives; Forget releases it for re-application.
            let mut grid = vec![0i64; (ROWS * COLS as u64) as usize];
            let mut live: std::collections::HashSet<u64> = Default::default();
            for op in script {
                match *op {
                    Op::Push { uid, row, col, val } => {
                        let fresh = push(&client, id, uid, row, col, val);
                        // A push is fresh exactly when its uid is not live.
                        if fresh == live.contains(&uid) {
                            return Err(format!(
                                "uid {uid}: fresh={fresh} but live={}",
                                live.contains(&uid)
                            ));
                        }
                        if fresh {
                            grid[(row * COLS as u64 + col as u64) as usize] += val;
                            live.insert(uid);
                        }
                    }
                    Op::Forget { uid } => {
                        client
                            .request_retry(0, &Request::Forget { uid })
                            .map_err(|e| e.to_string())?;
                        live.remove(&uid);
                    }
                }
            }
            stop(server, &client);

            // Kill -9 equivalent: all in-memory state is gone; the new
            // process must reproduce the counts from the log alone.
            let server = serve(&cfg);
            let client = client_for(&server);
            let m = client
                .attach_matrix::<i64>(id, ROWS, COLS, Layout::Dense)
                .map_err(|e| e.to_string())?;
            let rows: Vec<u64> = (0..ROWS).collect();
            let recovered = m.pull_rows(&rows).map_err(|e| e.to_string())?;
            if recovered != grid {
                return Err(format!("recovered {recovered:?}, expected {grid:?}"));
            }
            // The dedup window replayed too: every live uid dedups, a
            // never-seen uid applies.
            for &uid in &live {
                if push(&client, id, uid, 0, 0, 1) {
                    return Err(format!("replayed uid {uid} re-applied"));
                }
            }
            if !push(&client, id, 0xdead, 0, 0, 0) {
                return Err("fresh uid 0xdead was deduplicated".into());
            }
            stop(server, &client);
            let _ = std::fs::remove_dir_all(&dir);
            Ok(())
        },
    );
}
