//! Cross-module integration tests: parameter server + LightLDA trainer +
//! baselines + evaluators working together, including under injected
//! faults. (Per-module unit/property tests live next to their modules.)

use glint_lda::baselines::{em, online};
use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::corpus::tokenizer::TokenizerConfig;
use glint_lda::corpus::vocab::corpus_from_texts;
use glint_lda::eval::coherence::{mean_umass, DocFreq};
use glint_lda::eval::perplexity::holdout_perplexity;
use glint_lda::lda::sweep::SamplerParams;
use glint_lda::lda::trainer::{TrainConfig, Trainer};
use glint_lda::net::FaultPlan;
use glint_lda::ps::partition::PartitionScheme;

fn corpus() -> glint_lda::corpus::dataset::Corpus {
    generate(&SynthConfig {
        num_docs: 400,
        vocab_size: 900,
        num_topics: 8,
        avg_doc_len: 50.0,
        seed: 99,
        ..Default::default()
    })
}

fn base_cfg() -> TrainConfig {
    TrainConfig {
        num_topics: 10,
        iterations: 10,
        workers: 3,
        shards: 4,
        sampler: SamplerParams {
            block_words: 256,
            buffer_cap: 2000,
            dense_top_words: 50,
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn distributed_beats_uniform_and_matches_holdout() {
    let c = corpus();
    let (train, test) = c.split_holdout(5);
    let mut t = Trainer::new(base_cfg(), &train).unwrap();
    let model = t.run(&train).unwrap();
    let train_p = t.training_perplexity(&model, &train);
    assert!(train_p < train.vocab_size as f64 * 0.7);
    // Held-out perplexity: finite, worse than training, better than
    // uniform.
    let hold_p = holdout_perplexity(&model, &test, 5, 7);
    assert!(hold_p.is_finite());
    assert!(hold_p < test.vocab_size as f64);
}

#[test]
fn all_three_algorithms_land_in_the_same_perplexity_band() {
    // The paper's central quality claim (Table 1): roughly equal
    // perplexity across ours / EM / online on the same data.
    let c = corpus();
    let mut t = Trainer::new(TrainConfig { iterations: 15, ..base_cfg() }, &c).unwrap();
    let ours = {
        let m = t.run(&c).unwrap();
        t.training_perplexity(&m, &c)
    };
    let em_p = {
        let m = em::train(
            &em::EmConfig { num_topics: 10, iterations: 15, workers: 3, ..Default::default() },
            &c,
        )
        .unwrap();
        m.perplexity(&c)
    };
    let online_p = {
        let m = online::train(
            &online::OnlineConfig {
                num_topics: 10,
                epochs: 3,
                batch_size: 64,
                workers: 3,
                ..Default::default()
            },
            &c,
        )
        .unwrap();
        m.perplexity(&c, 3)
    };
    let lo = ours.min(em_p).min(online_p);
    let hi = ours.max(em_p).max(online_p);
    assert!(
        hi / lo < 1.5,
        "perplexities diverged: ours {ours:.1}, em {em_p:.1}, online {online_p:.1}"
    );
}

#[test]
fn training_survives_nasty_network() {
    let c = corpus();
    let cfg = TrainConfig {
        fault: FaultPlan::lossy(0.10, 0.10),
        iterations: 3,
        ..base_cfg()
    };
    let mut t = Trainer::new(cfg, &c).unwrap();
    for _ in 0..3 {
        t.run_iteration().unwrap();
    }
    // Exactly-once: server state identical to local assignments.
    t.verify_counts().unwrap();
}

#[test]
fn pipelining_and_buffering_do_not_change_counts() {
    // Ablations must preserve correctness invariants exactly.
    let c = corpus();
    for (pipeline_depth, buffer_cap, dense_top) in
        [(0usize, 100usize, 0u64), (2, 1_000_000, 900), (3, 7, 10)]
    {
        let base = base_cfg();
        let cfg = TrainConfig {
            sampler: SamplerParams {
                pipeline_depth,
                buffer_cap,
                dense_top_words: dense_top,
                ..base.sampler
            },
            iterations: 2,
            ..base
        };
        let mut t = Trainer::new(cfg, &c).unwrap();
        t.run_iteration().unwrap();
        t.run_iteration().unwrap();
        t.verify_counts().unwrap();
    }
}

#[test]
fn range_and_cyclic_schemes_converge_equally() {
    let c = corpus();
    let mut perps = Vec::new();
    for scheme in [PartitionScheme::Cyclic, PartitionScheme::Range] {
        let cfg = TrainConfig { scheme, iterations: 8, ..base_cfg() };
        let mut t = Trainer::new(cfg, &c).unwrap();
        let m = t.run(&c).unwrap();
        perps.push(t.training_perplexity(&m, &c));
    }
    let ratio = perps[0] / perps[1];
    assert!(
        (0.9..1.1).contains(&ratio),
        "schemes should match statistically: {perps:?}"
    );
}

#[test]
fn real_text_pipeline_to_model() {
    // Tokenize -> stopwords -> stem -> vocab -> train -> coherent topics.
    let texts: Vec<String> = (0..60)
        .map(|i| {
            if i % 2 == 0 {
                format!(
                    "Cooking recipe number {i}: spices, meat, flavor and a hot oven. \
                     The recipe uses spices to season the meat."
                )
            } else {
                format!(
                    "Match report {i}: the team scored at the stadium and the league \
                     title race is alive. Fans filled the stadium."
                )
            }
        })
        .collect();
    let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
    let c = corpus_from_texts(&refs, &TokenizerConfig::default(), 2, 5000);
    assert!(c.is_frequency_ordered());
    let cfg = TrainConfig {
        num_topics: 2,
        iterations: 30,
        workers: 2,
        shards: 2,
        sampler: SamplerParams { block_words: 32, ..Default::default() },
        ..Default::default()
    };
    let mut t = Trainer::new(cfg, &c).unwrap();
    let model = t.run(&c).unwrap();
    // Topic coherence should be far from catastrophic for 2 clean topics.
    let df = DocFreq::build(&c);
    let coherence = mean_umass(&model, &df, 5);
    assert!(coherence > -25.0, "topics incoherent: {coherence}");
    // The two topics should separate cooking from football vocabulary.
    let top0 = glint_lda::eval::topics::describe_topic(&model, &c.vocab, 0, 5);
    let top1 = glint_lda::eval::topics::describe_topic(&model, &c.vocab, 1, 5);
    assert_ne!(top0, top1);
}

#[test]
fn trainer_report_records_curve() {
    let c = corpus();
    let cfg = TrainConfig { eval_every: 2, iterations: 6, ..base_cfg() };
    let mut t = Trainer::new(cfg, &c).unwrap();
    t.run(&c).unwrap();
    let rows = t.report.rows();
    assert_eq!(rows.len(), 6);
    assert!(rows.iter().filter(|r| r.get("perplexity").is_some()).count() >= 3);
    // Hot-path observability: every row carries the alias-build and
    // pipeline-stall timers.
    assert!(rows.iter().all(|r| r.get("alias_build_secs").is_some()));
    assert!(rows.iter().all(|r| r.get("block_wait_secs").is_some()));
    let csv = t.report.to_csv();
    assert!(csv.contains("tokens_per_sec"));
    assert!(csv.contains("alias_build_secs"));
    assert!(csv.contains("block_wait_secs"));
}

fn alias_ablation_holdout_perplexity(alias_dense_threshold: f64) -> f64 {
    let c = corpus();
    let (train, test) = c.split_holdout(5);
    let base = base_cfg();
    let cfg = TrainConfig {
        iterations: 8,
        shards: 2,
        sampler: SamplerParams { pipeline_depth: 4, alias_dense_threshold, ..base.sampler },
        ..base
    };
    let mut t = Trainer::new(cfg, &train).unwrap();
    let model = t.run(&train).unwrap();
    // Whatever the proposal construction, the server tables must equal
    // the assignments exactly.
    t.verify_counts().unwrap();
    holdout_perplexity(&model, &test, 5, 7)
}

/// The hybrid sparse-plus-uniform word proposal must be
/// quality-neutral: training with every table built through the
/// LightLDA mixture (threshold > 1) reaches the same held-out
/// perplexity as the dense-alias ablation (threshold 0) on the 2-shard
/// sim — the two constructions sample the identical `n̂_wk + β`
/// distribution, so only the build cost may differ.
#[test]
fn hybrid_and_dense_alias_training_reach_parity() {
    let dense = alias_ablation_holdout_perplexity(0.0);
    let hybrid = alias_ablation_holdout_perplexity(2.0);
    assert!(dense.is_finite() && hybrid.is_finite());
    let ratio = hybrid / dense;
    assert!(
        (0.9..1.1).contains(&ratio),
        "hybrid-alias perplexity {hybrid:.1} diverged from dense-alias {dense:.1} \
         (ratio {ratio:.3})"
    );
}
