//! Property-style protocol tests for the parameter server under
//! adversarial fault schedules: the exactly-once push guarantee and
//! retried-pull correctness are the paper's §2.3/§2.4 claims.

use glint_lda::net::FaultPlan;
use glint_lda::ps::client::{BigMatrix, CoordDeltas, PsClient};
use glint_lda::ps::config::PsConfig;
use glint_lda::ps::partition::PartitionScheme;
use glint_lda::ps::server::ServerGroup;
use glint_lda::util::rng::Pcg64;

fn setup(shards: usize, plan: FaultPlan, seed: u64) -> (ServerGroup, PsClient) {
    let cfg = PsConfig {
        shards,
        timeout: std::time::Duration::from_millis(20),
        ..PsConfig::default()
    };
    let group = ServerGroup::start(cfg.clone(), plan, seed);
    let client = PsClient::connect(&group.transport(), cfg);
    (group, client)
}

/// Apply a random delta workload through a lossy network and verify the
/// final server state equals the locally tracked ground truth — for
/// many random fault schedules.
#[test]
fn exactly_once_over_many_fault_schedules() {
    for case in 0..12 {
        let mut rng = Pcg64::new(0xf00 + case);
        let drop = rng.f64() * 0.25;
        let dup = rng.f64() * 0.15;
        let shards = 1 + rng.below(5);
        let plan = FaultPlan::lossy(drop, dup);
        let (_g, client) = setup(shards, plan, 0xabc + case);
        let rows = 40u64;
        let cols = 3u32;
        let m: BigMatrix<i64> = client.matrix(rows, cols).unwrap();
        let mut expect = vec![0i64; (rows * cols as u64) as usize];
        for _ in 0..15 {
            let n = 1 + rng.below(50);
            let mut deltas = CoordDeltas::default();
            for _ in 0..n {
                let r = rng.below(rows as usize) as u64;
                let c = rng.below(cols as usize) as u32;
                let v = rng.below(5) as i64 - 2;
                deltas.rows.push(r);
                deltas.cols.push(c);
                deltas.values.push(v);
                expect[(r * cols as u64 + c as u64) as usize] += v;
            }
            m.push_coords(&deltas).unwrap();
        }
        let all: Vec<u64> = (0..rows).collect();
        let got = m.pull_rows(&all).unwrap();
        assert_eq!(
            got, expect,
            "state diverged under drop={drop:.2} dup={dup:.2} shards={shards} (case {case})"
        );
    }
}

/// Pulls are read-only: arbitrary retries must return consistent data.
#[test]
fn pulls_consistent_under_loss() {
    let (_g, client) = setup(3, FaultPlan::lossy(0.2, 0.2), 0x9);
    let m: BigMatrix<i64> = client.matrix(20, 2).unwrap();
    let deltas = CoordDeltas {
        rows: (0..20).collect(),
        cols: (0..20).map(|i| (i % 2) as u32).collect(),
        values: (0..20).map(|i| i as i64).collect(),
    };
    m.push_coords(&deltas).unwrap();
    let all: Vec<u64> = (0..20).collect();
    let first = m.pull_rows(&all).unwrap();
    for _ in 0..10 {
        assert_eq!(m.pull_rows(&all).unwrap(), first);
    }
}

/// Concurrent pushers from many threads over a lossy network: total must
/// still be exact (commutativity + exactly-once).
#[test]
fn concurrent_lossy_pushers_are_exact() {
    let (_g, client) = setup(4, FaultPlan::lossy(0.08, 0.08), 0x77);
    let m: BigMatrix<i64> = client.matrix(64, 1).unwrap();
    let threads = 6;
    let per_thread = 40;
    std::thread::scope(|scope| {
        for t in 0..threads {
            let m = m.clone();
            scope.spawn(move || {
                let mut rng = Pcg64::new(t as u64);
                for _ in 0..per_thread {
                    let deltas = CoordDeltas {
                        rows: vec![rng.below(64) as u64],
                        cols: vec![0],
                        values: vec![1],
                    };
                    m.push_coords(&deltas).unwrap();
                }
            });
        }
    });
    let all: Vec<u64> = (0..64).collect();
    let got = m.pull_rows(&all).unwrap();
    assert_eq!(got.iter().sum::<i64>(), (threads * per_thread) as i64);
}

/// Both partitioning schemes route every row to exactly one shard and
/// survive the same lossy workload.
#[test]
fn schemes_equivalent_under_faults() {
    for scheme in [PartitionScheme::Cyclic, PartitionScheme::Range] {
        let cfg = PsConfig {
            shards: 5,
            scheme,
            timeout: std::time::Duration::from_millis(20),
            ..PsConfig::default()
        };
        let group = ServerGroup::start(cfg.clone(), FaultPlan::lossy(0.1, 0.1), 0x31);
        let client = PsClient::connect(&group.transport(), cfg);
        let m: BigMatrix<i64> = client.matrix(101, 2).unwrap();
        let deltas = CoordDeltas {
            rows: (0..101).collect(),
            cols: vec![1; 101],
            values: vec![7; 101],
        };
        m.push_coords(&deltas).unwrap();
        let all: Vec<u64> = (0..101).collect();
        let got = m.pull_rows(&all).unwrap();
        for r in 0..101usize {
            assert_eq!(got[r * 2], 0);
            assert_eq!(got[r * 2 + 1], 7, "row {r} scheme {scheme:?}");
        }
    }
}

/// Shard info reflects reality after uid cleanup (Forget phase).
#[test]
fn no_uid_leaks_after_pushes() {
    let (_g, client) = setup(3, FaultPlan::lossy(0.1, 0.1), 0x55);
    let m: BigMatrix<i64> = client.matrix(30, 2).unwrap();
    for i in 0..20 {
        let deltas = CoordDeltas { rows: vec![i % 30], cols: vec![0], values: vec![1] };
        m.push_coords(&deltas).unwrap();
    }
    let infos = client.shard_infos().unwrap();
    let pending: u64 = infos.iter().map(|i| i.pending_uids).sum();
    assert_eq!(pending, 0, "all push uids must be forgotten after acks");
}
