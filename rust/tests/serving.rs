//! Serve-model integration tests: fold-in parity against the exact
//! evaluation path, request batching/coalescing and cache behavior of
//! the inference engine, and the full replica/client topology over TCP
//! with concurrent clients.

use std::sync::Arc;

use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::eval::perplexity::{
    holdout_perplexity, log_likelihood_docs, perplexity_from_loglik,
};
use glint_lda::lda::hyper::LdaHyper;
use glint_lda::lda::infer::{FoldInBudget, InferConfig, InferEngine};
use glint_lda::lda::sparse_counts::DocTopicCounts;
use glint_lda::lda::sweep::SamplerParams;
use glint_lda::lda::trainer::{TrainConfig, Trainer};
use glint_lda::net::tcp::TcpTransport;
use glint_lda::net::FaultPlan;
use glint_lda::ps::client::{BigMatrix, CoordDeltas, PsClient};
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::messages::Layout;
use glint_lda::ps::partition::PartitionScheme;
use glint_lda::ps::server::{ServerGroup, TcpShardServer};
use glint_lda::serving::{InferClient, InferServer, DEFAULT_BATCH_WINDOW};

fn parity_corpus() -> glint_lda::corpus::dataset::Corpus {
    generate(&SynthConfig {
        num_docs: 360,
        vocab_size: 800,
        num_topics: 8,
        avg_doc_len: 45.0,
        seed: 525,
        ..Default::default()
    })
}

/// The acceptance bar for the fold-in kernel: held-out perplexity of the
/// serve-model answers (MH fold-in over frozen alias tables, computed
/// through the engine against live 2-shard state) must match the exact
/// Gibbs fold-in of the evaluation path on the same frozen model.
#[test]
fn serve_model_heldout_perplexity_matches_exact_fold_in() {
    let corpus = parity_corpus();
    let (train, test) = corpus.split_holdout(5);
    let cfg = TrainConfig {
        num_topics: 10,
        iterations: 8,
        workers: 3,
        shards: 2,
        sampler: SamplerParams {
            block_words: 256,
            buffer_cap: 2000,
            dense_top_words: 50,
            ..Default::default()
        },
        ..Default::default()
    };
    let hyper = cfg.hyper();
    let mut trainer = Trainer::new(cfg, &train).unwrap();
    let model = trainer.run(&train).unwrap();

    // A second, serving-profile client against the same live shards —
    // the freeze/attach handshake is the trainer's matrix id.
    let group = trainer.server_group().expect("in-process servers");
    let serve_cfg = PsConfig::serving(2, PartitionScheme::Cyclic, TransportMode::Sim);
    let client = PsClient::connect(&*group.transport(), serve_cfg);
    let mut engine = InferEngine::attach(
        &client,
        trainer.matrix_id(),
        train.vocab_size,
        10,
        Layout::Sparse,
        hyper,
        InferConfig { budget: FoldInBudget { sweeps: 5, mh_steps: 2 }, ..Default::default() },
    )
    .unwrap();

    // Answer the held-out set in batches, then score the answers with
    // the evaluation path's own likelihood.
    let mut counts: Vec<DocTopicCounts> = Vec::new();
    for chunk in test.docs.chunks(16) {
        let refs: Vec<&[u32]> = chunk.iter().map(|d| d.tokens.as_slice()).collect();
        for pairs in engine.infer_batch(&refs).unwrap() {
            counts.push(DocTopicCounts::from_pairs(&pairs));
        }
    }
    let (ll, tokens) = log_likelihood_docs(&model, &test.docs, &counts);
    let served = perplexity_from_loglik(ll, tokens);
    let exact = holdout_perplexity(&model, &test, 5, 7);
    assert!(served.is_finite() && exact.is_finite());
    let ratio = served / exact;
    assert!(
        (0.85..1.15).contains(&ratio),
        "serve-model perplexity {served:.1} diverged from exact fold-in {exact:.1} \
         (ratio {ratio:.3})"
    );

    let stats = engine.stats();
    assert_eq!(stats.docs, test.docs.len() as u64);
    assert!(stats.sparse_pulls <= stats.batches);
    assert!(stats.words_pulled <= u64::from(train.vocab_size));
}

/// A frozen peaked model pushed straight onto 2 sim shards: word `w`
/// belongs to topic `w % k` with mass `peak`.
fn peaked_group(
    v: u32,
    k: u32,
    peak: i64,
) -> (ServerGroup, PsClient, BigMatrix<i64>, LdaHyper) {
    let cfg = PsConfig::with_shards(2);
    let group = ServerGroup::start(cfg.clone(), FaultPlan::reliable(), 17);
    let client = PsClient::connect(&*group.transport(), cfg);
    let m: BigMatrix<i64> = client.matrix_with_layout(u64::from(v), k, Layout::Sparse).unwrap();
    let deltas = CoordDeltas {
        rows: (0..v).map(u64::from).collect(),
        cols: (0..v).map(|w| w % k).collect(),
        values: vec![peak; v as usize],
    };
    m.push_coords(&deltas).unwrap();
    (group, client, m, LdaHyper { alpha: 0.1, beta: 0.01 })
}

fn attach(client: &PsClient, id: u32, v: u32, k: u32, hyper: LdaHyper) -> InferEngine {
    InferEngine::attach(client, id, v, k, Layout::Sparse, hyper, InferConfig::default())
        .unwrap()
}

/// Batching must coalesce the model reads: across a whole batch, every
/// distinct word is pulled exactly once, in one sparse pull — duplicate
/// words across documents cost nothing extra.
#[test]
fn batch_coalesces_duplicate_words_into_one_pull() {
    let (v, k) = (60u32, 4u32);
    let (_group, client, m, hyper) = peaked_group(v, k, 300);
    let mut engine = attach(&client, m.id(), v, k, hyper);

    // Three documents with heavy word overlap: 8 distinct words total.
    let docs: Vec<&[u32]> = vec![
        &[0, 4, 8, 12, 0, 4, 8, 12],
        &[0, 4, 16, 20, 16, 20, 0, 4],
        &[8, 12, 24, 28, 24, 28, 8, 12],
    ];
    engine.infer_batch(&docs).unwrap();
    let s = engine.stats();
    assert_eq!(s.batches, 1);
    assert_eq!(s.sparse_pulls, 1, "one coalesced pull per batch");
    assert_eq!(s.words_pulled, 8, "each distinct word pulled once");

    // A second batch re-using cached words only pulls the new ones.
    let docs2: Vec<&[u32]> = vec![&[0, 4, 32, 36], &[8, 12, 32, 36]];
    engine.infer_batch(&docs2).unwrap();
    let s = engine.stats();
    assert_eq!(s.sparse_pulls, 2);
    assert_eq!(s.words_pulled, 10, "only words 32 and 36 are new");
}

/// Repeat documents are answered from the fold-in LRU without touching
/// the shards, and the answer is byte-identical.
#[test]
fn repeat_documents_hit_the_fold_in_cache() {
    let (v, k) = (40u32, 4u32);
    let (_group, client, m, hyper) = peaked_group(v, k, 300);
    let mut engine = attach(&client, m.id(), v, k, hyper);

    let doc: Vec<u32> = vec![1, 5, 9, 13, 1, 5, 9, 13, 1, 5];
    let first = engine.infer_one(&doc).unwrap();
    let pulls_after_first = engine.stats().sparse_pulls;
    let second = engine.infer_one(&doc).unwrap();
    let s = engine.stats();
    assert_eq!(first, second);
    assert_eq!(s.cache_hits, 1);
    assert_eq!(s.sparse_pulls, pulls_after_first, "cached answer pulls nothing");
    assert_eq!(s.docs, 2);
}

/// Answers are well-formed: topics ascending and in range, counts
/// summing to the document length; out-of-vocabulary tokens are a
/// loud error, not a crash.
#[test]
fn answers_are_well_formed_and_oov_is_rejected() {
    let (v, k) = (40u32, 4u32);
    let (_group, client, m, hyper) = peaked_group(v, k, 300);
    let mut engine = attach(&client, m.id(), v, k, hyper);

    let doc: Vec<u32> = (0..25).map(|i| (i * 7) % v).collect();
    let pairs = engine.infer_one(&doc).unwrap();
    assert!(pairs.windows(2).all(|w| w[0].0 < w[1].0), "topics ascending");
    assert!(pairs.iter().all(|&(t, c)| t < k && c > 0));
    assert_eq!(pairs.iter().map(|&(_, c)| u64::from(c)).sum::<u64>(), doc.len() as u64);

    assert!(engine.infer_one(&[v]).is_err(), "token id == V is out of vocabulary");
}

/// Attaching to an id that holds no counts must fail loudly: an id typo
/// would otherwise create a fresh empty matrix server-side and silently
/// serve uniform topics.
#[test]
fn attach_rejects_an_empty_model() {
    let cfg = PsConfig::with_shards(2);
    let group = ServerGroup::start(cfg.clone(), FaultPlan::reliable(), 19);
    let client = PsClient::connect(&*group.transport(), cfg);
    let err = InferEngine::attach(
        &client,
        77,
        40,
        4,
        Layout::Sparse,
        LdaHyper { alpha: 0.1, beta: 0.01 },
        InferConfig::default(),
    );
    assert!(err.is_err(), "an empty table is not a frozen model");
}

/// The full serving topology over real sockets: 2 TCP shards holding the
/// frozen model, one replica, 4 concurrent clients. Every request must
/// be answered correctly, and the replica's counters must account for
/// every document.
#[test]
fn serve_model_answers_concurrent_clients_over_tcp() {
    let (v, k) = (80u32, 4u32);
    let cfg = PsConfig::with_shards(2);
    let binds: Vec<std::net::SocketAddr> =
        (0..2).map(|_| "127.0.0.1:0".parse().unwrap()).collect();
    let shard_server = TcpShardServer::bind(cfg.clone(), 0, &binds).unwrap();
    let transport = TcpTransport::connect(shard_server.addrs());
    let client = PsClient::connect(&transport, cfg);
    let m: BigMatrix<i64> = client.matrix_with_layout(u64::from(v), k, Layout::Sparse).unwrap();
    let deltas = CoordDeltas {
        rows: (0..v).map(u64::from).collect(),
        cols: (0..v).map(|w| w % k).collect(),
        values: vec![250; v as usize],
    };
    m.push_coords(&deltas).unwrap();

    let hyper = LdaHyper { alpha: 0.1, beta: 0.01 };
    let serve_transport = TcpTransport::connect(shard_server.addrs());
    let serve_client = PsClient::connect(
        &serve_transport,
        PsConfig::serving(
            2,
            PartitionScheme::Cyclic,
            TransportMode::Connect(shard_server.addrs().iter().map(|a| a.to_string()).collect()),
        ),
    );
    let engine = InferEngine::attach(
        &serve_client,
        m.id(),
        v,
        k,
        Layout::Sparse,
        hyper,
        InferConfig::default(),
    )
    .unwrap();
    let replica = InferServer::start(engine, "127.0.0.1:0", DEFAULT_BATCH_WINDOW).unwrap();
    let addr = replica.addr().to_string();

    let pool: Arc<Vec<Vec<u32>>> = Arc::new(
        (0..10u32).map(|d| (0..12u32).map(|i| (d * 3 + i * 5) % v).collect()).collect(),
    );
    let requests_per_client = 10usize;
    let handles: Vec<_> = (0..4)
        .map(|c| {
            let pool = Arc::clone(&pool);
            let addr = addr.clone();
            std::thread::spawn(move || {
                let client = InferClient::connect(&addr).unwrap();
                for i in 0..requests_per_client {
                    let doc = &pool[(c + i) % pool.len()];
                    let pairs = client.infer_one(doc).unwrap();
                    let total: u64 = pairs.iter().map(|&(_, n)| u64::from(n)).sum();
                    assert_eq!(total, doc.len() as u64);
                    assert!(pairs.iter().all(|&(t, _)| t < k));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let ctl = InferClient::connect(&addr).unwrap();
    let stats = ctl.stats().unwrap();
    assert_eq!(stats.requests, 40);
    assert_eq!(stats.docs, 40);
    assert!(stats.sparse_pulls >= 1);
    assert!(stats.sparse_pulls <= stats.batches);
    assert!(stats.cache_hits > 0, "10 unique docs over 40 requests must hit the cache");

    ctl.shutdown().unwrap();
    replica.join();
    client.shutdown_servers().unwrap();
    shard_server.join();
}
