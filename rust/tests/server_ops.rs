//! Integration tests for the sparse-aware server-side operation
//! protocol: pluggable storage layouts (`Layout::Dense` vs
//! `Layout::Sparse`), the typed pull ops (`PullSparseRows`, `PullTopK`,
//! `PullColSums`) against naive references, exactly-once semantics on
//! the sparse store, and end-to-end training parity between the two
//! word-topic layouts.

use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::eval::perplexity::holdout_perplexity;
use glint_lda::lda::sweep::SamplerParams;
use glint_lda::lda::trainer::{TrainConfig, Trainer};
use glint_lda::net::FaultPlan;
use glint_lda::ps::client::{BigMatrix, CoordDeltas, PsClient};
use glint_lda::ps::config::PsConfig;
use glint_lda::ps::messages::Layout;
use glint_lda::ps::server::ServerGroup;
use glint_lda::util::rng::Pcg64;

fn setup(shards: usize, plan: FaultPlan, seed: u64) -> (ServerGroup, PsClient) {
    let cfg = PsConfig {
        shards,
        timeout: std::time::Duration::from_millis(20),
        ..PsConfig::default()
    };
    let group = ServerGroup::start(cfg.clone(), plan, seed);
    let client = PsClient::connect(&group.transport(), cfg);
    (group, client)
}

/// Apply an identical random workload to a dense-layout and a
/// sparse-layout matrix; every read op must agree between the two, and
/// the sparse results must agree with references computed client-side
/// from the dense pull.
#[test]
fn sparse_ops_match_dense_reference_over_random_workloads() {
    for case in 0..8u64 {
        let mut rng = Pcg64::new(0x0b5 + case);
        let shards = 1 + rng.below(4);
        let rows = 10 + rng.below(60) as u64;
        let cols = 2 + rng.below(30) as u32;
        let (_g, client) = setup(shards, FaultPlan::reliable(), 0xce + case);
        let dense: BigMatrix<i64> = client.matrix_with_layout(rows, cols, Layout::Dense).unwrap();
        let sparse: BigMatrix<i64> =
            client.matrix_with_layout(rows, cols, Layout::Sparse).unwrap();
        for _ in 0..6 {
            let n = 1 + rng.below(120);
            let mut deltas = CoordDeltas::default();
            for _ in 0..n {
                deltas.rows.push(rng.below(rows as usize) as u64);
                deltas.cols.push(rng.below(cols as usize) as u32);
                deltas.values.push(rng.below(7) as i64 - 3);
            }
            dense.push_coords(&deltas).unwrap();
            sparse.push_coords(&deltas).unwrap();
        }

        let all: Vec<u64> = (0..rows).collect();
        let reference = dense.pull_rows(&all).unwrap();
        assert_eq!(sparse.pull_rows(&all).unwrap(), reference, "dense pulls, case {case}");

        // Sparse pulls: densify and compare; pairs must be sorted by
        // column and free of explicit zeros.
        for (m, label) in [(&dense, "dense-layout"), (&sparse, "sparse-layout")] {
            let pulled = m.pull_sparse_rows(&all).unwrap();
            assert_eq!(pulled.len(), rows as usize);
            for (r, pairs) in pulled.iter().enumerate() {
                let mut densified = vec![0i64; cols as usize];
                for &(c, v) in pairs {
                    assert_ne!(v, 0, "{label} shipped a zero, case {case}");
                    densified[c as usize] = v;
                }
                assert_eq!(
                    densified,
                    reference[r * cols as usize..(r + 1) * cols as usize],
                    "{label} sparse pull row {r}, case {case}"
                );
                for w in pairs.windows(2) {
                    assert!(w[0].0 < w[1].0, "{label} columns not ascending, case {case}");
                }
            }
        }
    }
}

/// `PullTopK` must agree with the naive client-side reference: sort the
/// row's non-zero entries by value descending (ties by column
/// ascending) and truncate to k.
#[test]
fn topk_matches_naive_sort() {
    let mut rng = Pcg64::new(0x70b);
    let (_g, client) = setup(3, FaultPlan::reliable(), 0x70c);
    let rows = 40u64;
    let cols = 24u32;
    for layout in [Layout::Dense, Layout::Sparse] {
        let m: BigMatrix<i64> = client.matrix_with_layout(rows, cols, layout).unwrap();
        let mut deltas = CoordDeltas::default();
        for _ in 0..600 {
            deltas.rows.push(rng.below(rows as usize) as u64);
            deltas.cols.push(rng.below(cols as usize) as u32);
            deltas.values.push(rng.below(9) as i64 - 4);
        }
        m.push_coords(&deltas).unwrap();

        let all: Vec<u64> = (0..rows).collect();
        let reference = m.pull_rows(&all).unwrap();
        for k in [1u32, 3, 7, 100] {
            let got = m.pull_topk(&all, k).unwrap();
            for r in 0..rows as usize {
                let mut expect: Vec<(u32, i64)> = reference
                    [r * cols as usize..(r + 1) * cols as usize]
                    .iter()
                    .enumerate()
                    .filter(|(_, &v)| v != 0)
                    .map(|(c, &v)| (c as u32, v))
                    .collect();
                expect.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                expect.truncate(k as usize);
                assert_eq!(got[r], expect, "row {r} k {k} layout {layout:?}");
            }
        }
    }
}

/// `PullColSums` must equal summing a full client-side pull, for both
/// layouts and several shard counts.
#[test]
fn col_sums_match_client_side_reference() {
    let mut rng = Pcg64::new(0xc01);
    for shards in [1usize, 3, 5] {
        let (_g, client) = setup(shards, FaultPlan::reliable(), 0xc02 + shards as u64);
        for layout in [Layout::Dense, Layout::Sparse] {
            let rows = 57u64;
            let cols = 9u32;
            let m: BigMatrix<i64> = client.matrix_with_layout(rows, cols, layout).unwrap();
            let mut deltas = CoordDeltas::default();
            for _ in 0..400 {
                deltas.rows.push(rng.below(rows as usize) as u64);
                deltas.cols.push(rng.below(cols as usize) as u32);
                deltas.values.push(rng.below(11) as i64 - 5);
            }
            m.push_coords(&deltas).unwrap();

            let all: Vec<u64> = (0..rows).collect();
            let full = m.pull_rows(&all).unwrap();
            let mut expect = vec![0i64; cols as usize];
            for (i, &v) in full.iter().enumerate() {
                expect[i % cols as usize] += v;
            }
            assert_eq!(
                m.pull_col_sums().unwrap(),
                expect,
                "{shards} shards, layout {layout:?}"
            );
        }
    }
}

/// The exactly-once push protocol holds on the sparse store under an
/// adversarial fault schedule, and sparse pulls see the same state.
#[test]
fn sparse_layout_exactly_once_under_loss() {
    let (_g, client) = setup(3, FaultPlan::lossy(0.2, 0.12), 0x1055);
    let rows = 30u64;
    let cols = 4u32;
    let m: BigMatrix<i64> = client.matrix_with_layout(rows, cols, Layout::Sparse).unwrap();
    let mut rng = Pcg64::new(0x10c);
    let mut expect = vec![0i64; (rows * cols as u64) as usize];
    for _ in 0..15 {
        let n = 1 + rng.below(40);
        let mut deltas = CoordDeltas::default();
        for _ in 0..n {
            let r = rng.below(rows as usize) as u64;
            let c = rng.below(cols as usize) as u32;
            let v = rng.below(5) as i64 - 2;
            deltas.rows.push(r);
            deltas.cols.push(c);
            deltas.values.push(v);
            expect[(r * cols as u64 + c as u64) as usize] += v;
        }
        m.push_coords(&deltas).unwrap();
    }
    let all: Vec<u64> = (0..rows).collect();
    assert_eq!(m.pull_rows(&all).unwrap(), expect);
    // The sparse view agrees entry-by-entry too.
    let pulled = m.pull_sparse_rows(&all).unwrap();
    let mut densified = vec![0i64; expect.len()];
    for (r, pairs) in pulled.iter().enumerate() {
        for &(c, v) in pairs {
            densified[r * cols as usize + c as usize] = v;
        }
    }
    assert_eq!(densified, expect);
}

/// A Zipf-occupancy sparse matrix must be resident-smaller than its
/// dense twin (the §3/Figure 4 premise made measurable via ShardInfo).
#[test]
fn sparse_layout_uses_fewer_resident_bytes_at_zipf_occupancy() {
    let rows = 500u64;
    let cols = 64u32;
    let mut bytes = Vec::new();
    for layout in [Layout::Dense, Layout::Sparse] {
        let (_g, client) = setup(2, FaultPlan::reliable(), 0x21f);
        let m: BigMatrix<i64> = client.matrix_with_layout(rows, cols, layout).unwrap();
        let mut deltas = CoordDeltas::default();
        for r in 0..rows {
            let nnz = (cols as u64 / (r + 1)).max(1);
            for j in 0..nnz {
                deltas.rows.push(r);
                deltas.cols.push(((r + j) % cols as u64) as u32);
                deltas.values.push(1);
            }
        }
        m.push_coords(&deltas).unwrap();
        let infos = client.shard_infos().unwrap();
        bytes.push(infos.iter().map(|i| i.bytes).sum::<u64>());
        assert_eq!(infos.iter().map(|i| i.dedup_evictions).sum::<u64>(), 0);
    }
    assert!(
        bytes[1] * 4 < bytes[0],
        "sparse layout resident bytes {} should be well under dense {}",
        bytes[1],
        bytes[0]
    );
}

fn parity_corpus() -> glint_lda::corpus::dataset::Corpus {
    generate(&SynthConfig {
        num_docs: 360,
        vocab_size: 800,
        num_topics: 8,
        avg_doc_len: 45.0,
        seed: 727,
        ..Default::default()
    })
}

fn train_holdout_perplexity(layout: Layout) -> f64 {
    let corpus = parity_corpus();
    let (train, test) = corpus.split_holdout(5);
    let cfg = TrainConfig {
        num_topics: 10,
        iterations: 8,
        workers: 3,
        shards: 2,
        sampler: SamplerParams {
            block_words: 256,
            buffer_cap: 2000,
            dense_top_words: 50,
            pipeline_depth: 4,
            ..Default::default()
        },
        wt_layout: layout,
        ..Default::default()
    };
    let mut trainer = Trainer::new(cfg, &train).unwrap();
    trainer.verify_counts().unwrap();
    let model = trainer.run(&train).unwrap();
    // The server-side tables must match the assignments exactly under
    // either storage layout.
    trainer.verify_counts().unwrap();
    holdout_perplexity(&model, &test, 5, 7)
}

/// Training with the sparse word-topic layout reaches the same held-out
/// perplexity as the dense layout on the 2-shard sim deployment: the
/// storage/protocol change must be quality-neutral.
#[test]
fn sparse_and_dense_layout_training_reach_parity() {
    let dense = train_holdout_perplexity(Layout::Dense);
    let sparse = train_holdout_perplexity(Layout::Sparse);
    assert!(dense.is_finite() && sparse.is_finite());
    let ratio = sparse / dense;
    assert!(
        (0.9..1.1).contains(&ratio),
        "sparse-layout perplexity {sparse:.1} diverged from dense-layout {dense:.1} \
         (ratio {ratio:.3})"
    );
}
