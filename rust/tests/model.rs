//! Deterministic model-checking suite (`cargo test --features model`).
//!
//! Each test wraps a small concurrent scenario over the *real* crate
//! code in [`explore`], which runs the body thousands of times under
//! the sync_shim's virtual scheduler — one task runnable at a time,
//! every lock/channel/condvar operation a schedule point — and fails
//! with a replayable schedule token (`GLINT_MODEL_REPLAY`) on the first
//! schedule that deadlocks, panics, or trips a [`model_assert`].
//!
//! The covered subsystems mirror the production call paths:
//!
//! - the [`ThreadPool`] used by trainer sweeps (lost-wakeup regression);
//! - [`MuxPending`], the TCP mux's correlation table (no silent waits);
//! - the shard read pool and bounded dedup window of `ps::server`;
//! - the WAL's group-commit handoff and compaction (`wal`), including
//!   an injected `kill -9` of the committer *inside* the group-commit
//!   window ([`WalOptions::crash_after_writes`]);
//! - replication: the `ReplApply` path with racing/zombie pollers, a
//!   depth-2 standby chain with head-ward promotion, `ReplSeed`
//!   re-pointing with generation fencing, and the planned `Drain`
//!   hand-off;
//! - the serve-model replica's inbox-drain batching loop
//!   ([`serve_loop`] over a scripted [`BatchEngine`]);
//! - the elastic membership control plane;
//!
//! plus a Wing & Gong–style linearizability oracle checking the
//! exactly-once push protocol against a sequential counter spec under
//! scheduler-chosen message loss, duplication, reordering and
//! crash-replay. The chain / re-seed / drain replication models each
//! feed their recorded histories through the same oracle.
//!
//! Coverage floors: each subsystem model asserts that at least 1,000
//! *distinct* schedules were explored (skipped under replay, where
//! exactly one schedule runs by design).

#![cfg(feature = "model")]

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use glint_lda::net::infer::{InferRequest, InferResponse, ServeStats};
use glint_lda::net::tcp::MuxPending;
use glint_lda::net::{Envelope, Inbox};
use glint_lda::serving::{serve_loop, BatchEngine};
use glint_lda::ps::config::PsConfig;
use glint_lda::ps::messages::{Data, Dtype, Layout, Request, Response};
use glint_lda::ps::server::{ShardState, ROLE_PROMOTED};
use glint_lda::util::sync_shim::lin::{linearizable_counter, Op, Recorder, RetVal};
use glint_lda::util::sync_shim::sched::{
    choice, explore, model_assert, replay_active, ExploreOpts, ExploreStats,
};
use glint_lda::util::sync_shim::{mpsc, thread, Mutex};
use glint_lda::util::threadpool::ThreadPool;
use glint_lda::wal::{ShardWal, WalOptions, WalPayload};

/// Assert the exploration visited at least `floor` distinct schedules.
/// Skipped under `GLINT_MODEL_REPLAY` (a replay runs one schedule of
/// one model; every other explore returns zeroed stats).
fn coverage(name: &str, stats: ExploreStats, floor: usize) {
    if replay_active() {
        return;
    }
    assert!(
        stats.distinct >= floor,
        "model '{name}': only {} distinct schedules over {} runs (want >= {floor})",
        stats.distinct,
        stats.runs
    );
}

/// A fresh scratch directory for WAL-backed models. Uniqueness comes
/// from the pid plus a process-local counter — `Date.now`-style clocks
/// are forbidden inside model bodies (they would break replay), and a
/// counter keeps the name deterministic per run index anyway.
fn fresh_dir(tag: &str) -> PathBuf {
    static SEQ: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    let n = SEQ.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
    std::env::temp_dir().join(format!("glint-model-{tag}-{}-{n}", std::process::id()))
}

fn push_one(state: &mut ShardState, uid: u64, delta: i64) -> bool {
    let resp = state.handle(Request::PushCoords {
        id: 1,
        uid,
        rows: vec![0],
        cols: vec![0],
        values: Data::I64(vec![delta]),
    });
    match resp {
        Response::PushAck { fresh } => fresh,
        _ => {
            model_assert(false, "push rejected");
            false
        }
    }
}

fn create_counter(state: &mut ShardState) {
    let resp = state.handle(Request::CreateMatrix {
        id: 1,
        rows: 2,
        cols: 1,
        dtype: Dtype::I64,
        layout: Layout::Dense,
    });
    model_assert(matches!(resp, Response::Ok), "create rejected");
}

fn read_counter(state: &mut ShardState) -> i64 {
    match state.handle(Request::PullRows { id: 1, rows: vec![0] }) {
        Response::Rows(Data::I64(v)) => v[0],
        _ => {
            model_assert(false, "pull rejected");
            0
        }
    }
}

// ---------------------------------------------------------------------
// ThreadPool: the satellite-1 regression. The seed's `wait_idle`
// busy-waited on an atomic and its shutdown used a racy flag; the
// rewrite keeps queue + in-flight + shutdown under one mutex with two
// condvars. A lost wakeup in either place shows up here as a deadlock.
// ---------------------------------------------------------------------

fn threadpool_jobs_model() {
    let pool = Arc::new(ThreadPool::new(2));
    let counter = Arc::new(Mutex::new(0usize));
    // A second submitter races the root's own submissions.
    let submitter = {
        let pool = Arc::clone(&pool);
        let counter = Arc::clone(&counter);
        thread::spawn(move || {
            for _ in 0..2 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    *c.lock().unwrap() += 1;
                });
            }
        })
    };
    for _ in 0..2 {
        let c = Arc::clone(&counter);
        pool.execute(move || {
            *c.lock().unwrap() += 1;
        });
    }
    submitter.join().unwrap();
    pool.wait_idle();
    model_assert(*counter.lock().unwrap() == 4, "wait_idle returned before all jobs ran");
    // Dropping the pool must terminate: a lost shutdown wakeup would
    // leave a worker parked forever and fail as a deadlock.
    drop(pool);
}

#[test]
fn threadpool_wait_idle_and_shutdown() {
    let stats = explore(
        "threadpool-jobs",
        ExploreOpts { schedules: 2500, ..ExploreOpts::default() },
        threadpool_jobs_model,
    );
    coverage("threadpool-jobs", stats, 1000);
    // Systematic pass: bounded-preemption DFS over the same model.
    explore(
        "threadpool-jobs-dfs",
        ExploreOpts { schedules: 400, dfs: true, max_preemptions: 2, ..ExploreOpts::default() },
        threadpool_jobs_model,
    );
}

fn threadpool_drop_model() {
    let pool = ThreadPool::new(2);
    let counter = Arc::new(Mutex::new(0usize));
    for _ in 0..4 {
        let c = Arc::clone(&counter);
        pool.execute(move || {
            *c.lock().unwrap() += 1;
        });
    }
    // No wait_idle: Drop alone must drain the queue before joining.
    drop(pool);
    model_assert(*counter.lock().unwrap() == 4, "drop lost queued jobs");
}

#[test]
fn threadpool_drop_drains_queue() {
    let stats = explore(
        "threadpool-drop",
        ExploreOpts { schedules: 2500, ..ExploreOpts::default() },
        threadpool_drop_model,
    );
    coverage("threadpool-drop", stats, 1000);
}

// ---------------------------------------------------------------------
// MuxPending: the TCP mux's waiter table. The invariant under test is
// "no silent wait": however `kill` (reader death) interleaves with
// `register`, a waiter either observes `dead` on its post-insert check
// or has its reply sender dropped — it never blocks forever. A
// violation manifests as a deadlock, which the checker reports.
// ---------------------------------------------------------------------

fn mux_model() {
    let mux = Arc::new(MuxPending::new());
    // The "wire": requesters announce their correlation id to the
    // reader only after registering, exactly as `roundtrip` writes the
    // frame only after inserting the waiter.
    let (wire_tx, wire_rx) = mpsc::channel::<u64>();
    let mut requesters = Vec::new();
    for corr in 0u64..2 {
        let mux = Arc::clone(&mux);
        let wire = wire_tx.clone();
        requesters.push(thread::spawn(move || {
            let (tx, rx) = mpsc::sync_channel(1);
            mux.register(corr, tx);
            if mux.is_dead() {
                // Reader died around our registration: fail fast.
                mux.remove(corr);
                return;
            }
            let _ = wire.send(corr);
            match rx.recv() {
                Ok(payload) => model_assert(payload == [corr as u8], "cross-matched reply"),
                // kill() dropped our sender: the fail-fast wakeup.
                Err(_) => {}
            }
        }));
    }
    drop(wire_tx);
    let reader = {
        let mux = Arc::clone(&mux);
        thread::spawn(move || {
            while let Ok(corr) = wire_rx.recv() {
                if choice(3) == 0 {
                    // Socket error: the reader loop's exit path.
                    mux.kill();
                    return;
                }
                let _ = mux.deliver(corr, vec![corr as u8]);
            }
            if choice(2) == 0 {
                mux.kill();
            }
        })
    };
    for h in requesters {
        let _ = h.join();
    }
    let _ = reader.join();
}

#[test]
fn mux_pending_no_silent_wait() {
    let stats = explore(
        "mux-pending",
        ExploreOpts { schedules: 2500, ..ExploreOpts::default() },
        mux_model,
    );
    coverage("mux-pending", stats, 1000);
    explore(
        "mux-pending-dfs",
        ExploreOpts { schedules: 400, dfs: true, max_preemptions: 2, ..ExploreOpts::default() },
        mux_model,
    );
}

// ---------------------------------------------------------------------
// Shard read pool: reads served by pool workers concurrently with the
// owner thread's writes must never observe a torn value, and dropping
// the pool must answer everything still queued.
// ---------------------------------------------------------------------

fn readpool_model() {
    let mut state = ShardState::new(0, PsConfig::with_shards(1));
    create_counter(&mut state);
    model_assert(push_one(&mut state, 1, 5), "seed push deduped");
    let pool = state.start_read_pool(2);
    let mut replies = Vec::new();
    for _ in 0..2 {
        let (tx, rx) = mpsc::sync_channel(1);
        pool.submit(
            Envelope { payload: Vec::new(), reply: Some(tx) },
            Request::PullRows { id: 1, rows: vec![0] },
        );
        replies.push(rx);
    }
    // Concurrent with the in-flight reads.
    model_assert(push_one(&mut state, 2, 3), "second push deduped");
    for rx in replies {
        let bytes = rx.recv().expect("read pool dropped a reply");
        match Response::decode(&bytes) {
            Ok(Response::Rows(Data::I64(v))) => {
                model_assert(v[0] == 5 || v[0] == 8, "read observed a torn write");
            }
            _ => model_assert(false, "read pool returned a non-Rows reply"),
        }
    }
    drop(pool);
}

#[test]
fn shard_read_pool_serves_under_writes() {
    let stats = explore(
        "shard-readpool",
        ExploreOpts { schedules: 2500, ..ExploreOpts::default() },
        readpool_model,
    );
    coverage("shard-readpool", stats, 1000);
}

// ---------------------------------------------------------------------
// Bounded dedup window (satellite 4): a randomized property test under
// the model scheduler. While pending uids stay within the window cap,
// exactly-once holds for any interleaving and any scheduler-chosen
// number of duplicate deliveries; overflowing the cap evicts the oldest
// record, counts it, and (the documented weakening) a retry of an
// evicted uid re-applies.
// ---------------------------------------------------------------------

fn dedup_model() {
    let mut cfg = PsConfig::with_shards(1);
    cfg.dedup_window = 2;
    let mut state = ShardState::new(0, cfg);
    create_counter(&mut state);
    let reader = state.reader();
    let state = Arc::new(Mutex::new(state));

    let mut couriers = Vec::new();
    let fresh_acks = Arc::new(Mutex::new([0usize; 2]));
    for c in 0..2u64 {
        let state = Arc::clone(&state);
        let fresh_acks = Arc::clone(&fresh_acks);
        couriers.push(thread::spawn(move || {
            // 1..=3 deliveries of the same uid: retries after lost acks.
            let deliveries = 1 + choice(3);
            for _ in 0..deliveries {
                if push_one(&mut state.lock().unwrap(), 10 + c, 1) {
                    fresh_acks.lock().unwrap()[c as usize] += 1;
                }
            }
            let resp = state.lock().unwrap().handle(Request::Forget { uid: 10 + c });
            model_assert(matches!(resp, Response::Ok), "forget rejected");
        }));
    }
    // A concurrent reader observes only committed prefixes: 0, 1 or 2.
    let observer = thread::spawn(move || {
        for _ in 0..2 {
            match reader.handle_read(&Request::PullRows { id: 1, rows: vec![0] }) {
                Response::Rows(Data::I64(v)) => {
                    model_assert(v[0] >= 0 && v[0] <= 2, "reader saw an uncommitted value");
                }
                _ => model_assert(false, "concurrent read rejected"),
            }
        }
    });
    for h in couriers {
        let _ = h.join();
    }
    let _ = observer.join();

    let mut state = Arc::try_unwrap(state).ok().expect("state still shared").into_inner().unwrap();
    let acks = *fresh_acks.lock().unwrap();
    model_assert(acks == [1, 1], "a duplicate delivery was applied as fresh");
    model_assert(read_counter(&mut state) == 2, "exactly-once violated within the window");
    match state.handle(Request::ShardInfo) {
        Response::Info { pending_uids, dedup_evictions, .. } => {
            model_assert(pending_uids == 0, "forgotten uids still pending");
            model_assert(dedup_evictions == 0, "window evicted within its cap");
        }
        _ => model_assert(false, "shard info rejected"),
    }

    // Overflow: three un-forgotten uids through a cap-2 window.
    for uid in [20, 21, 22] {
        model_assert(push_one(&mut state, uid, 10), "overflow push deduped");
    }
    match state.handle(Request::ShardInfo) {
        Response::Info { pending_uids, dedup_evictions, .. } => {
            model_assert(pending_uids == 2, "window exceeded its cap");
            model_assert(dedup_evictions == 1, "eviction not counted");
        }
        _ => model_assert(false, "shard info rejected"),
    }
    // The documented weakening: a retry of the evicted uid re-applies.
    model_assert(push_one(&mut state, 20, 10), "evicted uid was still deduplicated");
    model_assert(read_counter(&mut state) == 42, "overflow accounting wrong");
}

#[test]
fn dedup_window_bounded_property() {
    let stats = explore(
        "shard-dedup",
        ExploreOpts { schedules: 2500, ..ExploreOpts::default() },
        dedup_model,
    );
    coverage("shard-dedup", stats, 1000);
}

// ---------------------------------------------------------------------
// WAL group commit: concurrent appenders, a virtual committer task,
// `sync` as a durability barrier, and recovery replaying a dense,
// ordered sequence. Disk writes are real; only the scheduling is
// virtual.
// ---------------------------------------------------------------------

fn wal_commit_model() {
    let dir = fresh_dir("wal");
    let opts = WalOptions { commit_window: Duration::from_millis(1), ..WalOptions::default() };
    {
        let (wal, replay) = ShardWal::open(&dir, 0, opts.clone()).expect("open wal");
        model_assert(replay.is_empty(), "fresh dir replayed records");
        let wal = Arc::new(wal);
        let mut appenders = Vec::new();
        for t in 0..2u8 {
            let wal = Arc::clone(&wal);
            appenders.push(thread::spawn(move || {
                for i in 0..2u8 {
                    wal.append(&WalPayload::Write(vec![t, i]));
                }
            }));
        }
        for h in appenders {
            let _ = h.join();
        }
        wal.sync();
        model_assert(wal.committed() == 4, "sync returned before the appends were durable");
    } // Drop joins the committer after it drains.
    let (_wal, replay) = ShardWal::open(&dir, 0, opts).expect("reopen wal");
    model_assert(replay.len() == 4, "reopen lost committed records");
    for (i, (seq, _)) in replay.iter().enumerate() {
        model_assert(*seq == i as u64 + 1, "replay sequence not dense and ordered");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wal_group_commit_durability() {
    let stats = explore(
        "wal-commit",
        ExploreOpts { schedules: 1500, ..ExploreOpts::default() },
        wal_commit_model,
    );
    coverage("wal-commit", stats, 1000);
}

fn wal_compact_model() {
    let dir = fresh_dir("walc");
    let opts = WalOptions { commit_window: Duration::from_millis(1), ..WalOptions::default() };
    {
        let (wal, _) = ShardWal::open(&dir, 0, opts.clone()).expect("open wal");
        for n in 0..6u8 {
            wal.append(&WalPayload::Write(vec![n; 8]));
        }
        // Compaction syncs first, so the snapshot claims exactly the
        // durable prefix (seq 6); the tail record lands after it.
        wal.compact(&[WalPayload::SnapNextUid(7)]).expect("compact");
        wal.append(&WalPayload::Write(vec![9; 8]));
        wal.sync();
        model_assert(wal.committed() == 7, "sync returned early after compaction");
    }
    let (_wal, replay) = ShardWal::open(&dir, 0, opts).expect("reopen wal");
    model_assert(replay.len() == 2, "compaction left stale or missing records");
    model_assert(replay[0].0 == 6, "snapshot record carries the wrong horizon");
    model_assert(replay[1].0 == 7, "tail record lost after compaction");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wal_compaction_replay() {
    let stats = explore(
        "wal-compact",
        ExploreOpts { schedules: 1500, ..ExploreOpts::default() },
        wal_compact_model,
    );
    coverage("wal-compact", stats, 1000);
}

// ---------------------------------------------------------------------
// Replication: two racing pollers (one is effectively a zombie
// duplicate) stream overlapping batches into a backup. The seq-skip
// plus uid-dedup layers must apply every record exactly once; the role
// gate must refuse data ops before promotion and refuse zombie applies
// after it.
// ---------------------------------------------------------------------

fn wal_write_record(req: &Request) -> Vec<u8> {
    WalPayload::Write(req.encode()).encode()
}

fn repl_model() {
    let mut cfg = PsConfig::with_shards(1);
    cfg.backup_of = Some(vec!["127.0.0.1:1".into()]);
    let mut state = ShardState::new(0, cfg);
    // The primary's committed log, as (seq, wal bytes) batches.
    let log: Vec<(u64, Vec<u8>)> = vec![
        (
            1,
            wal_write_record(&Request::CreateMatrix {
                id: 1,
                rows: 2,
                cols: 1,
                dtype: Dtype::I64,
                layout: Layout::Dense,
            }),
        ),
        (
            2,
            wal_write_record(&Request::PushCoords {
                id: 1,
                uid: 7,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![5]),
            }),
        ),
        (
            3,
            wal_write_record(&Request::PushCoords {
                id: 1,
                uid: 8,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![3]),
            }),
        ),
    ];
    let tip = 3u64;

    // Role gate: data ops are refused before promotion.
    match state.handle(Request::PullRows { id: 1, rows: vec![0] }) {
        Response::Unavailable(_) => {}
        _ => model_assert(false, "un-promoted backup served a data op"),
    }

    let state = Arc::new(Mutex::new(state));
    let mut pollers = Vec::new();
    for _ in 0..2 {
        let state = Arc::clone(&state);
        let log = log.clone();
        pollers.push(thread::spawn(move || loop {
            let applied = {
                let mut s = state.lock().unwrap();
                match s.handle(Request::ShardInfo) {
                    Response::Info { repl_applied, .. } => repl_applied,
                    _ => return,
                }
            };
            if applied >= tip {
                return;
            }
            let from = applied + 1;
            // Batch length is scheduler-chosen: 1..=remaining.
            let take = 1 + choice((tip - from) as usize + 1);
            let batch: Vec<(u64, Vec<u8>)> = log
                .iter()
                .filter(|(seq, _)| *seq >= from)
                .take(take)
                .cloned()
                .collect();
            let req = Request::ReplApply { gen: 0, reset: false, tip, records: batch.clone() };
            let resp = state.lock().unwrap().handle(req);
            model_assert(matches!(resp, Response::Ok), "backup refused a replication batch");
            if choice(2) == 0 {
                // Duplicate delivery of the whole batch.
                let dup = Request::ReplApply { gen: 0, reset: false, tip, records: batch };
                let resp = state.lock().unwrap().handle(dup);
                model_assert(matches!(resp, Response::Ok), "backup refused a duplicate batch");
            }
        }));
    }
    for h in pollers {
        let _ = h.join();
    }

    let mut state = Arc::try_unwrap(state).ok().expect("state still shared").into_inner().unwrap();
    let resp = state.handle(Request::Promote);
    model_assert(matches!(resp, Response::Ok), "promotion failed");
    match state.handle(Request::ShardInfo) {
        Response::Info { repl_applied, role, .. } => {
            model_assert(repl_applied == tip, "replica stopped short of the tip");
            model_assert(role == ROLE_PROMOTED, "promotion did not flip the role");
        }
        _ => model_assert(false, "shard info rejected"),
    }
    // Exactly-once across racing, duplicated, re-ordered batches.
    model_assert(read_counter(&mut state) == 8, "replicated pushes applied a wrong # of times");
    // A zombie poller arriving after promotion must be refused.
    let resp = state.handle(Request::ReplApply {
        gen: 0,
        reset: false,
        tip: tip + 1,
        records: vec![(tip + 1, wal_write_record(&Request::Forget { uid: 7 }))],
    });
    model_assert(
        matches!(resp, Response::Error(_)),
        "promoted replica accepted zombie replication",
    );
}

#[test]
fn repl_apply_exactly_once() {
    let stats = explore(
        "repl-apply",
        ExploreOpts { schedules: 2000, ..ExploreOpts::default() },
        repl_model,
    );
    coverage("repl-apply", stats, 1000);
}

// ---------------------------------------------------------------------
// Replication chains, re-seeding and planned drains, over a *real*
// WAL-backed head: concurrent exactly-once pushes land on the head,
// its committed log streams into standbys through the production
// `ReplPoll`/`ReplApply` pair, and every model records its pushes and
// the survivor's final read with the [`Recorder`] so the history must
// linearize against the exactly-once counter spec.
// ---------------------------------------------------------------------

/// A standby shard: gated until promoted, replication generation 0.
fn standby() -> ShardState {
    let mut cfg = PsConfig::with_shards(1);
    cfg.backup_of = Some(vec!["127.0.0.1:1".into()]);
    ShardState::new(0, cfg)
}

/// A WAL-backed head shard logging into `dir`, with the counter matrix
/// created (WAL seq 1).
fn wal_head(dir: &PathBuf) -> ShardState {
    let mut cfg = PsConfig::with_shards(1);
    cfg.wal_dir = Some(dir.clone());
    cfg.wal_commit_window = Duration::from_millis(1);
    let mut state = ShardState::new(0, cfg);
    create_counter(&mut state);
    state
}

/// Freeze the head and return its fsynced committed tip. `Drain` is the
/// production op with exactly the semantics the chain models need from
/// a "dead" head — single-writer freeze plus durability barrier — and a
/// drained head keeps serving `ReplPoll`, which is how the standbys
/// read the log it left behind.
fn freeze(head: &Mutex<ShardState>) -> u64 {
    match head.lock().unwrap().handle(Request::Drain) {
        Response::Drained { tip } => tip,
        _ => {
            model_assert(false, "wal-backed head refused to drain");
            0
        }
    }
}

/// Stream the frozen head's log into a standby until `repl_applied`
/// reaches `tip`, through the real poll/apply pair. Batch lengths per
/// round are scheduler-chosen; a snapshot batch (`reset`) stays whole.
fn pump_to_tip(head: &Mutex<ShardState>, standby: &Mutex<ShardState>, tip: u64, gen: u64) {
    loop {
        let applied = match standby.lock().unwrap().handle(Request::ShardInfo) {
            Response::Info { repl_applied, .. } => repl_applied,
            _ => {
                model_assert(false, "standby refused shard info");
                return;
            }
        };
        if applied >= tip {
            return;
        }
        let resp = head.lock().unwrap().handle(Request::ReplPoll { from: applied + 1 });
        let (reset, up_tip, mut records) = match resp {
            Response::ReplBatch { reset, tip, records, .. } => (reset, tip, records),
            _ => {
                model_assert(false, "frozen head refused a replication poll");
                return;
            }
        };
        model_assert(!records.is_empty(), "frozen head served an empty slice below its tip");
        if !reset {
            records.truncate(1 + choice(records.len()));
        }
        let req = Request::ReplApply { gen, reset, tip: up_tip, records };
        let resp = standby.lock().unwrap().handle(req);
        model_assert(matches!(resp, Response::Ok), "standby refused a replication batch");
    }
}

/// Two concurrent couriers pushing unique-uid deltas (total +3) into
/// the head, with scheduler-chosen re-deliveries and lost acks, each
/// recorded for the oracle. An un-acked push stays pending in the
/// history: it may linearize or vanish.
fn record_pushes(head: &Arc<Mutex<ShardState>>, recorder: &Arc<Recorder>) {
    let mut couriers = Vec::new();
    for c in 0..2u64 {
        let head = Arc::clone(head);
        let recorder = Arc::clone(recorder);
        couriers.push(thread::spawn(move || {
            let (uid, delta) = (300 + c, 1 + c as i64);
            let op = recorder.invoke(Op::Push { uid, delta });
            let mut acked = false;
            for _ in 0..1 + choice(2) {
                let _ = push_one(&mut head.lock().unwrap(), uid, delta);
                if choice(2) == 0 {
                    acked = true; // this delivery's ack made it back
                }
            }
            if acked {
                recorder.ret(op, RetVal::Done);
            }
        }));
    }
    for h in couriers {
        let _ = h.join();
    }
}

/// Record the promoted survivor's counter read, then run the oracle
/// over the completed history.
fn check_history(recorder: Arc<Recorder>, survivor: &Mutex<ShardState>) {
    let mut s = survivor.lock().unwrap();
    let op = recorder.invoke(Op::Read);
    let v = read_counter(&mut s);
    recorder.ret(op, RetVal::Value(v));
    drop(s);
    let history = Arc::try_unwrap(recorder).ok().expect("recorder still shared").finish();
    model_assert(
        linearizable_counter(&history),
        "history is not linearizable against the exactly-once counter spec",
    );
}

fn repl_chain_model() {
    let dir = fresh_dir("chain");
    let head = Arc::new(Mutex::new(wal_head(&dir)));
    let recorder = Arc::new(Recorder::new());
    record_pushes(&head, &recorder);
    let tip = freeze(&head);

    let b1 = Arc::new(Mutex::new(standby()));
    let b2 = Arc::new(Mutex::new(standby()));
    // Both tiers tail the head concurrently in scheduler-chosen batch
    // lengths; tier 1 may die with its head mid-stream.
    let t1 = {
        let head = Arc::clone(&head);
        let b1 = Arc::clone(&b1);
        thread::spawn(move || {
            if choice(2) == 0 {
                return false; // tier 1 died with the head
            }
            pump_to_tip(&head, &b1, tip, 0);
            true
        })
    };
    let t2 = {
        let head = Arc::clone(&head);
        let b2 = Arc::clone(&b2);
        thread::spawn(move || pump_to_tip(&head, &b2, tip, 0))
    };
    let tier1_alive = t1.join().unwrap_or(false);
    let _ = t2.join();

    // Promotion walks the chain head-ward: the first live standby wins
    // (the in-state mirror of `PsClient::promote_backup`'s probe walk).
    let winner = if tier1_alive { &b1 } else { &b2 };
    let resp = winner.lock().unwrap().handle(Request::Promote);
    model_assert(matches!(resp, Response::Ok), "chain promotion refused");
    if tier1_alive {
        // The deeper standby is still gated.
        match b2.lock().unwrap().handle(Request::PullRows { id: 1, rows: vec![0] }) {
            Response::Unavailable(_) => {}
            _ => model_assert(false, "un-promoted tier-2 standby served a data op"),
        }
    }
    // A zombie batch against the new head is refused.
    let resp = winner.lock().unwrap().handle(Request::ReplApply {
        gen: 0,
        reset: false,
        tip: tip + 1,
        records: vec![(tip + 1, wal_write_record(&Request::Forget { uid: 300 }))],
    });
    model_assert(
        matches!(resp, Response::Error(_)),
        "promoted chain head accepted zombie replication",
    );
    check_history(recorder, winner);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn repl_chain_promotes_head_ward() {
    let stats = explore(
        "repl-chain",
        ExploreOpts { schedules: 3000, ..ExploreOpts::default() },
        repl_chain_model,
    );
    coverage("repl-chain", stats, 2000);
}

fn repl_reseed_model() {
    let dir = fresh_dir("reseed");
    let head = Arc::new(Mutex::new(wal_head(&dir)));
    let recorder = Arc::new(Recorder::new());
    record_pushes(&head, &recorder);
    let tip = freeze(&head);

    // The head's full committed log: a scheduler-chosen prefix becomes
    // the seed's snapshot slice, and the whole of it doubles as a
    // zombie batch fetched from the *old* generation before the seed.
    let slice = match head.lock().unwrap().handle(Request::ReplPoll { from: 1 }) {
        Response::ReplBatch { records, .. } => records,
        _ => {
            model_assert(false, "frozen head refused a replication poll");
            return;
        }
    };
    model_assert(!slice.is_empty(), "frozen head served an empty log");
    let cut = 1 + choice(slice.len());
    let seed: Vec<(u64, Vec<u8>)> = slice[..cut].to_vec();

    let b = Arc::new(Mutex::new(standby()));
    let seeder = {
        let b = Arc::clone(&b);
        thread::spawn(move || {
            let resp = b.lock().unwrap().handle(Request::ReplSeed {
                upstream: "10.0.0.9:7071".into(),
                tip,
                records: seed,
            });
            model_assert(matches!(resp, Response::Ok), "standby refused a re-seed");
        })
    };
    let zombie = {
        let b = Arc::clone(&b);
        let batch = slice.clone();
        thread::spawn(move || {
            // A generation-0 batch from the old upstream racing the
            // seed: legal before it (the seed's reset wipes it), fenced
            // after it — never corrupting.
            let resp = b.lock().unwrap().handle(Request::ReplApply {
                gen: 0,
                reset: false,
                tip,
                records: batch,
            });
            match resp {
                Response::Ok => {}
                Response::Error(e) => model_assert(
                    e.contains("stale replication generation"),
                    "zombie batch refused for the wrong reason",
                ),
                _ => model_assert(false, "unexpected zombie-batch response"),
            }
        })
    };
    let _ = seeder.join();
    let _ = zombie.join();

    // The seeded standby is at generation 1; tail the rest of the log
    // under the new generation, take over, and check the counter.
    pump_to_tip(&head, &b, tip, 1);
    let resp = b.lock().unwrap().handle(Request::Promote);
    model_assert(matches!(resp, Response::Ok), "promotion after re-seed refused");
    check_history(recorder, &b);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn repl_seed_fences_and_rebuilds() {
    let stats = explore(
        "repl-reseed",
        ExploreOpts { schedules: 3000, ..ExploreOpts::default() },
        repl_reseed_model,
    );
    coverage("repl-reseed", stats, 2000);
}

fn drain_handoff_model() {
    let dir = fresh_dir("drainh");
    let head = Arc::new(Mutex::new(wal_head(&dir)));
    let recorder = Arc::new(Recorder::new());

    // A settled write from before the drain was scheduled.
    {
        let op = recorder.invoke(Op::Push { uid: 500, delta: 2 });
        let _ = push_one(&mut head.lock().unwrap(), 500, 2);
        recorder.ret(op, RetVal::Done);
    }

    // A late courier races the planned drain. Exactly one of three
    // things happens to its push, and all three must converge: acked
    // before the freeze; applied but the ack lost (the retry hits the
    // replicated dedup window); or frozen out with `Unavailable` (the
    // retry is a fresh apply on the new head).
    let late = {
        let head = Arc::clone(&head);
        let recorder = Arc::clone(&recorder);
        thread::spawn(move || {
            let op = recorder.invoke(Op::Push { uid: 501, delta: 3 });
            let resp = head.lock().unwrap().handle(Request::PushCoords {
                id: 1,
                uid: 501,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![3]),
            });
            match resp {
                Response::PushAck { .. } if choice(2) == 0 => {
                    recorder.ret(op, RetVal::Done);
                    None
                }
                Response::PushAck { .. } | Response::Unavailable(_) => Some(op),
                _ => {
                    model_assert(false, "unexpected push response during drain");
                    None
                }
            }
        })
    };
    let drainer = {
        let head = Arc::clone(&head);
        thread::spawn(move || {
            let tip = freeze(&head);
            if choice(2) == 0 {
                // Drain is idempotent and the frozen tip cannot move.
                model_assert(freeze(&head) == tip, "second drain moved the frozen tip");
            }
            tip
        })
    };
    let retry = late.join().ok().flatten();
    let tip = drainer.join().expect("drainer died");

    // Post-drain the head refuses data ops with the retryable signal...
    match head.lock().unwrap().handle(Request::PullRows { id: 1, rows: vec![0] }) {
        Response::Unavailable(_) => {}
        _ => model_assert(false, "draining head accepted a data op"),
    }

    // ...but keeps feeding its standby, whose applied tip then covers
    // the whole commit window — the hand-off that needs no epoch roll.
    let b = Arc::new(Mutex::new(standby()));
    pump_to_tip(&head, &b, tip, 0);
    let resp = b.lock().unwrap().handle(Request::Promote);
    model_assert(matches!(resp, Response::Ok), "promotion after drain refused");

    // The late courier retries its unsettled push on the new head; the
    // replicated dedup window absorbs the already-applied case.
    if let Some(op) = retry {
        let resp = b.lock().unwrap().handle(Request::PushCoords {
            id: 1,
            uid: 501,
            rows: vec![0],
            cols: vec![0],
            values: Data::I64(vec![3]),
        });
        model_assert(
            matches!(resp, Response::PushAck { .. }),
            "retry refused by the drained shard's successor",
        );
        recorder.ret(op, RetVal::Done);
    }

    // Zero loss, zero double-apply: whatever the interleaving, the
    // successor holds exactly both writes.
    model_assert(
        read_counter(&mut b.lock().unwrap()) == 5,
        "planned drain lost or double-applied a write",
    );
    check_history(recorder, &b);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn drain_hands_off_without_loss() {
    let stats = explore(
        "drain-handoff",
        ExploreOpts { schedules: 3000, ..ExploreOpts::default() },
        drain_handoff_model,
    );
    coverage("drain-handoff", stats, 2000);
}

// ---------------------------------------------------------------------
// WAL kill -9 inside the group-commit window: the committer dies after
// a scheduler-chosen number of segment writes, in the gap between a
// record write and its fsync ([`WalOptions::crash_after_writes`]), with
// its buffered tail discarded exactly like a hard process kill. Acked
// durability is whatever `committed()` published; recovery must replay
// a dense in-order prefix covering at least that — never ack-then-lose
// — and `sync` must unblock (not hang) on the dead committer.
// ---------------------------------------------------------------------

fn wal_kill_window_model() {
    let dir = fresh_dir("kill");
    let opts = WalOptions {
        commit_window: Duration::from_millis(1),
        // 4 records total: budgets 0..=3 kill the committer mid-stream
        // at every position; 4 never trips (the no-crash control).
        crash_after_writes: Some(choice(5) as u64),
        ..WalOptions::default()
    };
    let (wal, replay) = ShardWal::open(&dir, 0, opts).expect("open wal");
    model_assert(replay.is_empty(), "fresh dir replayed records");
    let wal = Arc::new(wal);
    let mut appenders = Vec::new();
    for t in 0..2u8 {
        let wal = Arc::clone(&wal);
        appenders.push(thread::spawn(move || {
            for i in 0..2u8 {
                wal.append(&WalPayload::Write(vec![t, i]));
            }
        }));
    }
    for h in appenders {
        let _ = h.join();
    }
    // The durability barrier must return even when the committer died
    // mid-way (its shutdown flag unblocks waiters); afterwards
    // `committed` is exactly the acked-durable frontier.
    wal.sync();
    let durable = wal.committed();
    drop(wal);

    let reopen = WalOptions { commit_window: Duration::from_millis(1), ..WalOptions::default() };
    let (_wal, replay) = ShardWal::open(&dir, 0, reopen).expect("reopen wal");
    model_assert(
        replay.len() as u64 >= durable,
        "recovery lost a record the committer had acked durable",
    );
    for (i, (seq, _)) in replay.iter().enumerate() {
        model_assert(*seq == i as u64 + 1, "replayed log is not a dense in-order prefix");
    }
    drop(_wal);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wal_kill_mid_window_never_loses_acked_records() {
    let stats = explore(
        "wal-kill-window",
        ExploreOpts { schedules: 2500, ..ExploreOpts::default() },
        wal_kill_window_model,
    );
    coverage("wal-kill-window", stats, 1000);
}

// ---------------------------------------------------------------------
// Serve-model batching loop: [`serve_loop`] coalesces an inbox into
// batches over a drain window and must answer every accepted request
// exactly once with its *own* result, ack the shutdown last, and drop
// (never half-serve) whatever raced past the shutdown.
// ---------------------------------------------------------------------

/// Scripted [`BatchEngine`]: echoes a fingerprint of each document so a
/// client can tell its own answer from a cross-matched one, and counts
/// every inference it runs.
#[derive(Clone, Default)]
struct ScriptEngine {
    /// `(batches run, docs inferred)`, shared with the root task.
    counts: Arc<Mutex<(u64, u64)>>,
}

impl BatchEngine for ScriptEngine {
    fn infer_batch(
        &mut self,
        docs: &[&[u32]],
    ) -> glint_lda::util::error::Result<Vec<Vec<(u32, u32)>>> {
        let mut c = self.counts.lock().unwrap();
        c.0 += 1;
        c.1 += docs.len() as u64;
        Ok(docs.iter().map(|d| vec![(d[0], d.len() as u32)]).collect())
    }

    fn serve_stats(&self, requests: u64) -> ServeStats {
        let c = self.counts.lock().unwrap();
        ServeStats { requests, docs: c.1, batches: c.0, ..ServeStats::default() }
    }
}

fn serve_batch_model() {
    let (tx, inbox) = Inbox::channel();
    let engine = ScriptEngine::default();
    let counts = Arc::clone(&engine.counts);
    let server = thread::spawn(move || serve_loop(&inbox, engine, Duration::from_millis(1)));

    let mut clients = Vec::new();
    for c in 0..2u32 {
        let tx = tx.clone();
        clients.push(thread::spawn(move || {
            // Fingerprint: first word == length == c + 1.
            let doc = vec![c + 1; (c + 1) as usize];
            let (rtx, rrx) = mpsc::sync_channel(1);
            let env = Envelope {
                payload: InferRequest::Infer { docs: vec![doc] }.encode(),
                reply: Some(rtx),
            };
            if tx.send(env).is_err() {
                return false; // loop already gone: request never accepted
            }
            match rrx.recv() {
                Ok(bytes) => match InferResponse::decode(&bytes) {
                    Ok(InferResponse::Topics { docs }) => {
                        model_assert(
                            docs.len() == 1 && docs[0] == vec![(c + 1, c + 1)],
                            "batch answered a request with another request's result",
                        );
                        true
                    }
                    _ => {
                        model_assert(false, "unexpected inference reply");
                        false
                    }
                },
                // The loop shut down before draining this request: the
                // envelope was dropped whole, never half-served (the
                // counter check below proves it).
                Err(_) => false,
            }
        }));
    }
    let stopper = thread::spawn(move || {
        let (rtx, rrx) = mpsc::sync_channel(1);
        let env = Envelope { payload: InferRequest::Shutdown.encode(), reply: Some(rtx) };
        // The loop cannot exit while this sender is alive, so the
        // shutdown is always accepted — and must always be acked.
        model_assert(tx.send(env).is_ok(), "serve loop exited before shutdown");
        match rrx.recv() {
            Ok(bytes) => model_assert(
                matches!(InferResponse::decode(&bytes), Ok(InferResponse::Ok)),
                "shutdown not acknowledged with Ok",
            ),
            Err(_) => model_assert(false, "shutdown request dropped unanswered"),
        }
    });

    let answered = clients
        .into_iter()
        .map(|h| h.join().unwrap_or(false))
        .filter(|&ok| ok)
        .count() as u64;
    let _ = stopper.join();
    let _ = server.join();
    // Exactly-once: every document the engine inferred corresponds to
    // one answered client and vice versa — nothing accepted was lost,
    // nothing was served twice.
    let (_batches, docs) = *counts.lock().unwrap();
    model_assert(
        docs == answered,
        "inferred docs and answered clients diverge: a request was lost or double-served",
    );
}

#[test]
fn serve_batch_answers_exactly_once() {
    let stats = explore(
        "serve-batch",
        ExploreOpts { schedules: 2500, ..ExploreOpts::default() },
        serve_batch_model,
    );
    coverage("serve-batch", stats, 1000);
}

// ---------------------------------------------------------------------
// Linearizability oracle (Wing & Gong): the exactly-once push protocol
// against a sequential counter spec. Couriers push unique-uid deltas
// with scheduler-chosen duplicate deliveries and lost replies; a reader
// pulls concurrently; the server may crash after serving two requests
// and recover from its WAL. The recorded concurrent history must admit
// a linearization in which every uid's delta is applied exactly once.
//
// Crash model: the teardown is a graceful drop — the WAL's group
// committer drains its queue before exiting, so recovery replays the
// full acknowledged prefix. This matches the durability contract the
// oracle checks (acked implies recovered); hard `kill -9` mid-window
// crashes are exercised by `tests/durability.rs` instead.
// ---------------------------------------------------------------------

fn lin_model() {
    let dir = fresh_dir("lin");
    let mut cfg = PsConfig::with_shards(1);
    cfg.wal_dir = Some(dir.clone());
    cfg.wal_commit_window = Duration::from_millis(1);

    let recorder = Arc::new(Recorder::new());
    let (srv_tx, srv_rx) = mpsc::channel::<(Request, mpsc::SyncSender<Response>)>();

    let server = {
        let cfg = cfg.clone();
        thread::spawn(move || {
            let mut state = ShardState::new(0, cfg.clone());
            create_counter(&mut state);
            let mut served = 0usize;
            while let Ok((req, reply)) = srv_rx.recv() {
                let resp = state.handle(req);
                let _ = reply.try_send(resp);
                served += 1;
                if served == 2 && choice(2) == 0 {
                    // Crash-replay: tear the shard down and recover it
                    // from the same WAL directory.
                    drop(state);
                    state = ShardState::new(0, cfg.clone());
                }
            }
        })
    };

    let mut clients = Vec::new();
    for c in 0..2u64 {
        let recorder = Arc::clone(&recorder);
        let tx = srv_tx.clone();
        clients.push(thread::spawn(move || {
            let uid = 100 + c;
            let delta = 1 + c as i64;
            let op = recorder.invoke(Op::Push { uid, delta });
            let mut acked = false;
            // 1..=2 deliveries: re-sends model retry-after-lost-ack.
            for _ in 0..1 + choice(2) {
                let (rtx, rrx) = mpsc::sync_channel(1);
                let req = Request::PushCoords {
                    id: 1,
                    uid,
                    rows: vec![0],
                    cols: vec![0],
                    values: Data::I64(vec![delta]),
                };
                if tx.send((req, rtx)).is_err() {
                    break;
                }
                if choice(2) == 0 {
                    if let Ok(Response::PushAck { .. }) = rrx.recv() {
                        acked = true;
                    }
                }
                // Else: the reply is lost in flight (rrx dropped; the
                // server's try_send to it is harmless).
            }
            if acked {
                recorder.ret(op, RetVal::Done);
            }
            // An un-acked push stays pending: the oracle lets it either
            // linearize or vanish.
        }));
    }
    {
        let recorder = Arc::clone(&recorder);
        let tx = srv_tx.clone();
        clients.push(thread::spawn(move || {
            let op = recorder.invoke(Op::Read);
            let (rtx, rrx) = mpsc::sync_channel(1);
            if tx.send((Request::PullRows { id: 1, rows: vec![0] }, rtx)).is_ok() {
                if let Ok(Response::Rows(Data::I64(v))) = rrx.recv() {
                    recorder.ret(op, RetVal::Value(v[0]));
                }
            }
        }));
    }
    for h in clients {
        let _ = h.join();
    }
    drop(srv_tx);
    let _ = server.join();

    let history = Arc::try_unwrap(recorder).ok().expect("recorder still shared").finish();
    model_assert(
        linearizable_counter(&history),
        "history is not linearizable against the exactly-once counter spec",
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn exactly_once_pushes_linearize() {
    let stats = explore(
        "lin-oracle",
        ExploreOpts { schedules: 1500, ..ExploreOpts::default() },
        lin_model,
    );
    coverage("lin-oracle", stats, 1000);
}


// ---------------------------------------------------------------------
// Elastic membership (cluster control plane). The real [`Membership`]
// state machine is driven by a simulated cluster whose event order —
// worker polls, reports, drains, registrations, crashes, wakeups, and
// reaper ticks — is scheduler-chosen via [`choice`]. The coordinator's
// automatic duties (roll the epoch as soon as one is wanted, deliver
// specs to admitted registrants) run after every event, exactly as
// `serve_one`/`run` do after every message and tick.
//
// Safety invariants, asserted after every step:
// - `Membership::check_invariants` (owners and targets are live
//   members, per-partition counters never run backwards);
// - no partition is believed-owned by two workers *within one epoch*
//   (a zombie's stale belief is tagged with the fenced old epoch, so
//   its pushes can never land in the live table);
// - a `Run` verdict only ever goes to a worker that believes it owns
//   the partition.
//
// Liveness: each scenario then pumps events round-robin (reaping the
// abandoned) and must reach `finished()` — every partition swept to the
// iteration target, none orphaned — within a bounded number of rounds.
// ---------------------------------------------------------------------

use std::cell::Cell;
use std::collections::{HashMap, HashSet};

use glint_lda::cluster::membership::{
    AckVerdict, Admission, DrainVerdict, Membership, MembershipCfg, PollVerdict,
};

/// One simulated worker process: what it believes, independent of the
/// coordinator's books.
struct SimWorker {
    token: u64,
    /// Seated member id (None: not registered, evicted, or exited).
    id: Option<u64>,
    /// Epoch of the spec this worker last built for.
    epoch: u32,
    /// Believed-owned partitions and the iteration each is at.
    parts: Vec<(u32, u32)>,
    /// Built runners but `Ready` not yet acknowledged.
    needs_ready: bool,
    /// Exited for good (crashed, drained, or run complete).
    gone: bool,
    /// Stalled: events disabled but state retained, so a later wakeup
    /// (or the completion pump) exercises the zombie-rejoin path.
    silent: bool,
}

struct SimCluster {
    ms: Membership,
    workers: Vec<SimWorker>,
    /// Latest checkpoint per partition (the shared disk).
    disk: HashMap<u32, u32>,
    now: u64,
    reap_timeout: u64,
}

impl SimCluster {
    fn new(cfg: MembershipCfg, parts: usize, reap_timeout: u64, tokens: &[u64]) -> SimCluster {
        let ranges = (0..parts).map(|i| i * 10..(i + 1) * 10).collect();
        let workers = tokens
            .iter()
            .map(|&token| SimWorker {
                token,
                id: None,
                epoch: 0,
                parts: Vec::new(),
                needs_ready: false,
                gone: false,
                silent: false,
            })
            .collect();
        SimCluster {
            ms: Membership::new(cfg, ranges),
            workers,
            disk: HashMap::new(),
            now: 0,
            reap_timeout,
        }
    }

    /// Deliver the current spec to a seated worker (the coordinator's
    /// `build_spec` plus the worker's rebuild/diff).
    fn deliver_spec(&mut self, wi: usize) {
        let w = self.workers[wi].id.expect("spec for unseated worker");
        let assigns = self.ms.spec_for(w);
        self.workers[wi].epoch = self.ms.epoch();
        self.workers[wi].parts = assigns
            .iter()
            .map(|a| (a.part, self.disk.get(&a.part).copied().unwrap_or(0)))
            .collect();
        self.workers[wi].needs_ready = true;
    }

    /// The coordinator's after-every-message duties: roll a wanted
    /// epoch (matrix creation modeled as always succeeding) and answer
    /// admitted or timed-out registrants.
    fn coordinator_duties(&mut self) {
        if self.ms.roll_wanted() {
            self.ms.rolled(self.now);
        }
        for (token, id) in self.ms.take_admitted() {
            if let Some(wi) = self.workers.iter().position(|w| w.token == token) {
                self.workers[wi].id = Some(id);
                self.deliver_spec(wi);
            }
        }
        if self.ms.finished() {
            for w in &mut self.workers {
                if w.id.is_none() && !w.gone {
                    // Parked registrants are answered `Done`.
                    w.gone = true;
                }
            }
        }
    }

    fn register(&mut self, wi: usize) {
        let token = self.workers[wi].token;
        match self.ms.register(token, self.now) {
            Admission::Seated { worker } | Admission::Existing { worker } => {
                self.workers[wi].id = Some(worker);
                self.deliver_spec(wi);
            }
            Admission::Parked => {}
            Admission::Finished => self.workers[wi].gone = true,
        }
    }

    /// The worker learned it was presumed dead; its loop re-registers
    /// with the same token (separate schedule point).
    fn evict(&mut self, wi: usize) {
        self.workers[wi].id = None;
        self.workers[wi].parts.clear();
    }

    fn send_ready(&mut self, wi: usize) {
        let w = self.workers[wi].id.expect("ready from unseated worker");
        let epoch = self.workers[wi].epoch;
        let items: Vec<(u32, u32, bool)> = self.workers[wi]
            .parts
            .iter()
            .map(|&(p, it)| (p, it, self.disk.contains_key(&p)))
            .collect();
        match self.ms.ready(w, epoch, &items, self.now) {
            AckVerdict::Ok => self.workers[wi].needs_ready = false,
            AckVerdict::Respec => self.deliver_spec(wi),
            AckVerdict::Unknown => self.evict(wi),
        }
    }

    /// One `Poll` round trip, including the sweep + checkpoint + report
    /// when `Run` comes back.
    fn poll(&mut self, wi: usize) {
        let w = self.workers[wi].id.expect("poll from unseated worker");
        match self.ms.poll(w, self.now) {
            PollVerdict::Respec => self.deliver_spec(wi),
            PollVerdict::Transfer(parts) => {
                self.workers[wi].parts.retain(|(p, _)| !parts.contains(p));
            }
            PollVerdict::Run { part, iteration } => {
                model_assert(
                    self.workers[wi].parts.iter().any(|&(p, _)| p == part),
                    "Run issued for a partition the worker does not believe it owns",
                );
                // Sweep, checkpoint, then report — checkpoint first,
                // exactly like the worker: the disk moves even when the
                // report is never delivered.
                self.disk.insert(part, iteration);
                let epoch = self.workers[wi].epoch;
                match self.ms.report(w, epoch, part, iteration, self.now) {
                    AckVerdict::Ok => {
                        for slot in self.workers[wi].parts.iter_mut() {
                            if slot.0 == part {
                                slot.1 = iteration;
                            }
                        }
                    }
                    AckVerdict::Respec => self.deliver_spec(wi),
                    AckVerdict::Unknown => self.evict(wi),
                }
            }
            PollVerdict::Wait => {}
            PollVerdict::Drained => {
                model_assert(
                    self.workers[wi].parts.is_empty(),
                    "Drained while the worker still believes it owns partitions",
                );
                self.workers[wi].id = None;
                self.workers[wi].gone = true;
            }
            PollVerdict::Done => {
                self.workers[wi].id = None;
                self.workers[wi].gone = true;
            }
            PollVerdict::Unknown => self.evict(wi),
        }
    }

    fn drain(&mut self, wi: usize) {
        let w = self.workers[wi].id.expect("drain from unseated worker");
        match self.ms.drain(w, self.now) {
            DrainVerdict::Draining => {}
            DrainVerdict::Drained => {
                self.workers[wi].id = None;
                self.workers[wi].parts.clear();
                self.workers[wi].gone = true;
            }
            DrainVerdict::Unknown => self.evict(wi),
        }
    }

    /// Reaper tick: advance time and reap the silent.
    fn tick(&mut self) {
        self.now += self.reap_timeout / 2 + 1;
        self.ms.reap(self.now, self.reap_timeout);
    }

    /// Safety net, asserted after every event.
    fn check(&self) {
        self.ms.check_invariants();
        let mut seen: HashSet<(u32, u32)> = HashSet::new();
        for w in &self.workers {
            if w.id.is_none() && !w.silent {
                continue;
            }
            for &(p, _) in &w.parts {
                model_assert(
                    seen.insert((w.epoch, p)),
                    "partition believed-owned by two workers in one epoch",
                );
            }
        }
    }

    /// One scheduler-chosen step over the enabled events: per live
    /// worker (register | ready | poll), a reaper tick, and the
    /// scenario's one-shot events.
    fn step(&mut self, extra: &mut [&mut dyn FnMut(&mut SimCluster)]) {
        let mut events: Vec<(usize, u8)> = Vec::new();
        for (wi, w) in self.workers.iter().enumerate() {
            if w.gone || w.silent {
                continue;
            }
            if w.id.is_none() {
                events.push((wi, 0));
            } else if w.needs_ready {
                events.push((wi, 1));
            } else {
                events.push((wi, 2));
            }
        }
        let base = events.len();
        let pick = choice(base + 1 + extra.len());
        if pick < base {
            let (wi, kind) = events[pick];
            match kind {
                0 => self.register(wi),
                1 => self.send_ready(wi),
                _ => self.poll(wi),
            }
        } else if pick == base {
            self.tick();
        } else {
            (extra[pick - base - 1])(self);
        }
        self.coordinator_duties();
        self.check();
    }

    /// Pump deterministically (no further scheduler choices) until the
    /// run finishes; a wedged control plane trips the round bound. The
    /// silent are woken (zombie rejoin must converge) and the abandoned
    /// are reaped.
    fn run_to_completion(&mut self) {
        for _ in 0..200 {
            if self.ms.finished()
                && self.workers.iter().all(|w| w.gone || w.id.is_none())
            {
                return;
            }
            self.now += self.reap_timeout / 2 + 1;
            for wi in 0..self.workers.len() {
                if self.workers[wi].gone {
                    continue;
                }
                self.workers[wi].silent = false;
                if self.workers[wi].id.is_none() {
                    self.register(wi);
                } else if self.workers[wi].needs_ready {
                    self.send_ready(wi);
                } else {
                    self.poll(wi);
                }
                self.coordinator_duties();
                self.check();
            }
            self.ms.reap(self.now, self.reap_timeout);
            self.coordinator_duties();
            self.check();
        }
        model_assert(false, "membership did not converge within the round bound");
    }
}

fn elastic_cfg(iterations: u32) -> MembershipCfg {
    MembershipCfg {
        elastic: true,
        workers: 2,
        vnodes: 8,
        iterations,
        max_staleness: 1,
        checkpointing: true,
        shed_factor: 0.0,
        shed_stall_ms: 1000,
    }
}

/// A joiner registers while a crashed worker is being reaped and the
/// epoch rolls: however the join interleaves with the orphaning, the
/// roll, and the re-specs, no partition is double-owned or left behind.
fn membership_join_during_roll_model() {
    let mut sim = SimCluster::new(elastic_cfg(2), 4, 4, &[11, 22, 33]);
    sim.register(0);
    sim.register(1);
    sim.coordinator_duties();
    sim.check();
    let crashed = Cell::new(false);
    let joined = Cell::new(false);
    for _ in 0..14 {
        let mut crash = |s: &mut SimCluster| {
            crashed.set(true);
            s.workers[1].silent = true;
        };
        let mut join = |s: &mut SimCluster| {
            joined.set(true);
            s.register(2);
        };
        let mut extra: Vec<&mut dyn FnMut(&mut SimCluster)> = Vec::new();
        if !crashed.get() {
            extra.push(&mut crash);
        }
        if !joined.get() {
            extra.push(&mut join);
        }
        sim.step(&mut extra);
    }
    // The crashed worker never comes back in this scenario; the pump
    // reaps it and the survivors finish the run.
    if crashed.get() {
        sim.workers[1].gone = true;
    }
    sim.run_to_completion();
}

#[test]
fn membership_join_during_epoch_roll() {
    let stats = explore(
        "membership-join-roll",
        ExploreOpts { schedules: 2500, ..ExploreOpts::default() },
        membership_join_during_roll_model,
    );
    coverage("membership-join-roll", stats, 1000);
}

/// A planned drain races the reaper: the draining worker's polls may be
/// delayed past the straggler timeout, so it can be reaped mid-drain.
/// Either way every partition stays (or ends up) owned exactly once.
fn membership_drain_races_reaper_model() {
    let mut sim = SimCluster::new(elastic_cfg(2), 4, 4, &[11, 22, 33]);
    for wi in 0..3 {
        sim.register(wi);
    }
    sim.coordinator_duties();
    sim.check();
    let asked = Cell::new(false);
    for _ in 0..14 {
        let mut ask = |s: &mut SimCluster| {
            // Only meaningful once seated with runners built; until
            // then the one-shot stays armed.
            if s.workers[1].id.is_some() && !s.workers[1].needs_ready {
                asked.set(true);
                s.drain(1);
            }
        };
        let mut extra: Vec<&mut dyn FnMut(&mut SimCluster)> = Vec::new();
        if !asked.get() {
            extra.push(&mut ask);
        }
        sim.step(&mut extra);
    }
    sim.run_to_completion();
}

#[test]
fn membership_drain_racing_reaper() {
    let stats = explore(
        "membership-drain-reaper",
        ExploreOpts { schedules: 2500, ..ExploreOpts::default() },
        membership_drain_races_reaper_model,
    );
    coverage("membership-drain-reaper", stats, 1000);
}

/// A reaped-but-alive worker (zombie) re-registers with its old token
/// while its partitions are being reassigned: the rejoin must never
/// alias the dead member id, double-own a partition, or wedge the run.
fn membership_zombie_rejoin_model() {
    let mut sim = SimCluster::new(elastic_cfg(2), 4, 4, &[11, 22, 33]);
    for wi in 0..3 {
        sim.register(wi);
    }
    sim.coordinator_duties();
    sim.check();
    // Stall worker 1 outright; scheduler-placed ticks decide when (and
    // whether) the reaper declares it dead before the wakeup.
    sim.workers[1].silent = true;
    let woke = Cell::new(false);
    for _ in 0..14 {
        let mut wake = |s: &mut SimCluster| {
            woke.set(true);
            s.workers[1].silent = false;
            // Its first call after the stall either discovers the
            // eviction (Unknown -> re-register, same token) or finds
            // the member still alive; both paths are legal.
            if s.workers[1].id.is_some() {
                s.poll(1);
            } else {
                s.register(1);
            }
        };
        let mut extra: Vec<&mut dyn FnMut(&mut SimCluster)> = Vec::new();
        if !woke.get() {
            extra.push(&mut wake);
        }
        sim.step(&mut extra);
    }
    sim.run_to_completion();
}

#[test]
fn membership_zombie_rejoin_vs_reassignment() {
    let stats = explore(
        "membership-zombie-rejoin",
        ExploreOpts { schedules: 2500, ..ExploreOpts::default() },
        membership_zombie_rejoin_model,
    );
    coverage("membership-zombie-rejoin", stats, 1000);
}
