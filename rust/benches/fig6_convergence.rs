//! Regenerates the paper's Figure 6: perplexity over wall-clock time for
//! the web-scale run (scaled; K=100 by default, K=1000 with
//! GLINT_BENCH_TOPICS=1000 if you have the time budget).

use glint_lda::experiments::fig6;

fn main() {
    glint_lda::util::logger::set_level_str("info");
    let scale: f64 = std::env::var("GLINT_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.6);
    let topics: u32 = std::env::var("GLINT_BENCH_TOPICS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);
    let r = fig6::run(&fig6::Fig6Config {
        scale,
        num_topics: topics,
        iterations: 25,
        ..fig6::Fig6Config::default()
    })
    .expect("fig6 run");
    println!("{}", r.report.to_table());
    println!(
        "final perplexity {:.1}; throughput {:.0} tokens/s",
        r.final_perplexity, r.tokens_per_sec
    );
    assert!(
        fig6::is_convergence_shaped(&r.report),
        "curve must be convergence-shaped"
    );
}
