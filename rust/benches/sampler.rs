//! The paper's amortized-O(1) claim (§3): per-token sampling cost of
//! LightLDA (MH + alias) vs exact collapsed Gibbs as K grows.
//!
//! Expected shape: Gibbs tokens/s degrades ~linearly with K; LightLDA
//! stays (nearly) flat — this is what makes K=1000 on 27 TB feasible.

use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::lda::gibbs::{sweep, LocalModel};
use glint_lda::lda::hyper::LdaHyper;
use glint_lda::lda::lightlda::sweep_light;
use glint_lda::util::rng::Pcg64;
use glint_lda::util::timer::Stopwatch;

fn main() {
    let corpus = generate(&SynthConfig {
        num_docs: 1500,
        vocab_size: 4000,
        num_topics: 32,
        avg_doc_len: 80.0,
        ..Default::default()
    });
    let tokens = corpus.num_tokens();
    println!("corpus: {} docs, {tokens} tokens", corpus.num_docs());
    println!(
        "{:>6} {:>16} {:>16} {:>8}",
        "K", "gibbs tok/s", "lightlda tok/s", "speedup"
    );
    let mut gibbs_rates = Vec::new();
    let mut light_rates = Vec::new();
    for &k in &[20u32, 40, 80, 160, 320, 640] {
        let hyper = LdaHyper::default_for(k as usize);
        // Exact Gibbs.
        let mut m = LocalModel::init_random(&corpus, k, hyper, 1);
        let mut rng = Pcg64::new(2);
        sweep(&mut m, &corpus, &mut rng); // warmup
        let sw = Stopwatch::new();
        sweep(&mut m, &corpus, &mut rng);
        let gibbs_rate = tokens as f64 / sw.secs();
        // LightLDA.
        let mut m = LocalModel::init_random(&corpus, k, hyper, 3);
        let mut rng = Pcg64::new(4);
        sweep_light(&mut m, &corpus, 2, &mut rng); // warmup
        let sw = Stopwatch::new();
        sweep_light(&mut m, &corpus, 2, &mut rng);
        let light_rate = tokens as f64 / sw.secs();
        println!(
            "{k:>6} {gibbs_rate:>16.0} {light_rate:>16.0} {:>7.1}x",
            light_rate / gibbs_rate
        );
        gibbs_rates.push(gibbs_rate);
        light_rates.push(light_rate);
    }
    // Shape assertions: Gibbs must degrade strongly with K (>=8x from
    // K=20 to K=640); LightLDA must stay within 4x.
    let g_drop = gibbs_rates[0] / gibbs_rates[gibbs_rates.len() - 1];
    let l_drop = light_rates[0] / light_rates[light_rates.len() - 1];
    println!("\ngibbs slowdown 20->640: {g_drop:.1}x; lightlda: {l_drop:.1}x");
    // Thresholds leave headroom for machine-load noise: the contrast to
    // verify is a ~32x linear degradation vs a small constant-ish factor.
    assert!(g_drop > 8.0, "gibbs should be ~linear in K (got {g_drop:.1}x)");
    assert!(l_drop < g_drop / 3.0, "lightlda should be ~flat in K (got {l_drop:.1}x)");
}
