//! The paper's amortized-O(1) claim (§3): per-token sampling cost of
//! LightLDA (MH + alias) vs exact collapsed Gibbs as K grows, plus the
//! Zipf K-scaling of the sampler hot path itself — word-proposal build
//! time and tokens/sec with the dense alias vs the hybrid
//! sparse-mixture alias ([`glint_lda::lda::alias::AliasBuilder`]).
//!
//! Expected shape: Gibbs tokens/s degrades ~linearly with K; LightLDA
//! stays (nearly) flat — this is what makes K=1000 on 27 TB feasible —
//! and the hybrid build stays flat in K for Zipf-tail words while the
//! dense build grows linearly.
//!
//! Environment knobs (used by CI):
//!
//! - `SMOKE=1` — fast regression path: skips the (slow) Gibbs-vs-
//!   LightLDA corpus sweeps and shrinks the token counts; the K-scaling
//!   section still covers K ∈ {64, 1024, 16384};
//! - `BENCH_JSON=path` — where to write the machine-readable summary
//!   (default `BENCH_sampler.json` in the working directory).

use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::lda::alias::{AliasBuilder, WordProposal};
use glint_lda::lda::gibbs::{sweep, LocalModel};
use glint_lda::lda::hyper::LdaHyper;
use glint_lda::lda::lightlda::{resample_token, sweep_light, TokenView};
use glint_lda::lda::sparse_counts::DocTopicCounts;
use glint_lda::util::rng::Pcg64;
use glint_lda::util::timer::{bench, Stopwatch};

/// One K's measurements for the Zipf K-scaling section.
struct KScale {
    k: usize,
    /// Nonzero topics of the tail word under test.
    nnz_tail: usize,
    /// Slots the two constructions actually table (K vs nnz) — exact
    /// structural numbers, useful for the analytic baseline.
    dense_tabled_slots: usize,
    hybrid_tabled_slots: usize,
    dense_build_secs: f64,
    hybrid_build_secs: f64,
    tail_build_speedup: f64,
    dense_tokens_per_sec: f64,
    hybrid_tokens_per_sec: f64,
    /// Whether the production 0.5 fill threshold picks the hybrid
    /// construction for this tail word.
    threshold_selects_hybrid: bool,
}

/// Token-resampling throughput for a prepared [`TokenView`] (one word
/// row plus its document context).
fn tokens_per_sec<P: WordProposal>(view: &TokenView<'_, P>, k: u32, tokens: usize) -> f64 {
    let doc_len = view.doc_assignments.len();
    let mut rng = Pcg64::new(8);
    let sw = Stopwatch::new();
    let mut acc = 0u64;
    for i in 0..tokens {
        acc += resample_token(view.doc_assignments[i % doc_len], view, k, 2, &mut rng) as u64;
    }
    std::hint::black_box(acc);
    tokens as f64 / sw.secs()
}

fn k_scaling(smoke: bool) -> Vec<KScale> {
    let beta = 0.01;
    let hyper = LdaHyper { alpha: 0.1, beta };
    let build_iters = if smoke { 15 } else { 40 };
    let tokens = if smoke { 50_000 } else { 400_000 };
    let mut out = Vec::new();
    println!("\nZipf K-scaling: tail-word hot path, dense vs hybrid proposal");
    println!(
        "{:>8} {:>9} {:>15} {:>15} {:>9} {:>14} {:>14}",
        "K", "nnz_tail", "dense build", "hybrid build", "speedup", "dense tok/s", "hybrid tok/s"
    );
    for &k in &[64usize, 1024, 16384] {
        let nnz = 16.min(k / 2);
        // A Zipf-tail row: a handful of nonzero topics spread over K.
        let pairs: Vec<(u32, i64)> =
            (0..nnz).map(|i| ((i * (k / nnz)) as u32, 1 + (i % 7) as i64)).collect();
        let mut row = vec![0i64; k];
        for &(c, v) in &pairs {
            row[c as usize] = v;
        }
        // The sampled document assigns its tokens to the row's nonzero
        // topics, matching the invariant the real sweep maintains
        // (a token's inclusive count is always >= 1).
        let assignments: Vec<u32> = (0..128).map(|i| pairs[i % nnz].0).collect();
        let counts = DocTopicCounts::from_assignments(&assignments);
        let n_k: Vec<i64> = vec![1000; k];

        let mut builder = AliasBuilder::new();
        let hybrid_build = bench(3, build_iters, || {
            let t = builder.build_hybrid(&pairs, k as u32, beta, 2.0);
            std::hint::black_box(t.total_weight())
        });
        let dense_build = bench(3, build_iters, || {
            let t = builder.build_dense(&row, beta);
            std::hint::black_box(t.total_weight())
        });

        let hybrid_rate = {
            let t = builder.build_hybrid(&pairs, k as u32, beta, 2.0);
            let view = TokenView {
                word_row: &row,
                n_k: &n_k,
                doc_counts: &counts,
                doc_assignments: &assignments,
                word_alias: &t,
                v: 100_000,
                hyper,
            };
            tokens_per_sec(&view, k as u32, tokens)
        };
        let dense_rate = {
            let t = builder.build_dense(&row, beta);
            let view = TokenView {
                word_row: &row,
                n_k: &n_k,
                doc_counts: &counts,
                doc_assignments: &assignments,
                word_alias: &t,
                v: 100_000,
                hyper,
            };
            tokens_per_sec(&view, k as u32, tokens)
        };
        let (dense_slots, hybrid_slots, threshold_selects_hybrid) = {
            let dense = builder.build_dense(&row, beta).tabled_slots();
            let hybrid = builder.build_hybrid(&pairs, k as u32, beta, 2.0).tabled_slots();
            let selected = builder.build_hybrid(&pairs, k as u32, beta, 0.5).is_hybrid();
            (dense, hybrid, selected)
        };

        let speedup = dense_build.mean / hybrid_build.mean;
        println!(
            "{k:>8} {nnz:>9} {:>15} {:>15} {:>8.1}x {:>14.0} {:>14.0}",
            glint_lda::util::timer::fmt_secs(dense_build.mean),
            glint_lda::util::timer::fmt_secs(hybrid_build.mean),
            speedup,
            dense_rate,
            hybrid_rate
        );
        out.push(KScale {
            k,
            nnz_tail: nnz,
            dense_tabled_slots: dense_slots,
            hybrid_tabled_slots: hybrid_slots,
            dense_build_secs: dense_build.mean,
            hybrid_build_secs: hybrid_build.mean,
            tail_build_speedup: speedup,
            dense_tokens_per_sec: dense_rate,
            hybrid_tokens_per_sec: hybrid_rate,
            threshold_selects_hybrid,
        });
    }
    // The tentpole claim: at web-scale K the tail-word build must track
    // nnz, not K — at least an order of magnitude over the dense build
    // (the raw work ratio at K=16384 / nnz=16 is 1024x).
    let last = out.last().unwrap();
    assert!(
        last.tail_build_speedup > 10.0,
        "hybrid tail build should be >=10x faster than dense at K={} (got {:.1}x)",
        last.k,
        last.tail_build_speedup
    );
    assert!(last.threshold_selects_hybrid, "0.5 fill threshold must keep tail words sparse");
    out
}

/// The classic Gibbs-vs-LightLDA corpus sweep comparison (full mode
/// only — minutes of sweeping).
fn o1_vs_ok() -> Vec<(u32, f64, f64)> {
    let corpus = generate(&SynthConfig {
        num_docs: 1500,
        vocab_size: 4000,
        num_topics: 32,
        avg_doc_len: 80.0,
        ..Default::default()
    });
    let tokens = corpus.num_tokens();
    println!("corpus: {} docs, {tokens} tokens", corpus.num_docs());
    println!(
        "{:>6} {:>16} {:>16} {:>8}",
        "K", "gibbs tok/s", "lightlda tok/s", "speedup"
    );
    let mut rows = Vec::new();
    for &k in &[20u32, 40, 80, 160, 320, 640] {
        let hyper = LdaHyper::default_for(k as usize);
        // Exact Gibbs.
        let mut m = LocalModel::init_random(&corpus, k, hyper, 1);
        let mut rng = Pcg64::new(2);
        sweep(&mut m, &corpus, &mut rng); // warmup
        let sw = Stopwatch::new();
        sweep(&mut m, &corpus, &mut rng);
        let gibbs_rate = tokens as f64 / sw.secs();
        // LightLDA.
        let mut m = LocalModel::init_random(&corpus, k, hyper, 3);
        let mut rng = Pcg64::new(4);
        sweep_light(&mut m, &corpus, 2, &mut rng); // warmup
        let sw = Stopwatch::new();
        sweep_light(&mut m, &corpus, 2, &mut rng);
        let light_rate = tokens as f64 / sw.secs();
        println!(
            "{k:>6} {gibbs_rate:>16.0} {light_rate:>16.0} {:>7.1}x",
            light_rate / gibbs_rate
        );
        rows.push((k, gibbs_rate, light_rate));
    }
    // Shape assertions: Gibbs must degrade strongly with K (>=8x from
    // K=20 to K=640); LightLDA must stay within 4x.
    let g_drop = rows[0].1 / rows[rows.len() - 1].1;
    let l_drop = rows[0].2 / rows[rows.len() - 1].2;
    println!("\ngibbs slowdown 20->640: {g_drop:.1}x; lightlda: {l_drop:.1}x");
    // Thresholds leave headroom for machine-load noise: the contrast to
    // verify is a ~32x linear degradation vs a small constant-ish factor.
    assert!(g_drop > 8.0, "gibbs should be ~linear in K (got {g_drop:.1}x)");
    assert!(l_drop < g_drop / 3.0, "lightlda should be ~flat in K (got {l_drop:.1}x)");
    rows
}

fn write_json(path: &str, smoke: bool, scaling: &[KScale], o1: &[(u32, f64, f64)]) {
    let mut body = String::from("{\n");
    body.push_str("  \"bench\": \"sampler\",\n");
    body.push_str(&format!("  \"smoke\": {smoke},\n"));
    body.push_str("  \"k_scaling\": [\n");
    for (i, r) in scaling.iter().enumerate() {
        let sep = if i + 1 < scaling.len() { "," } else { "" };
        body.push_str(&format!(
            "    {{\"k\": {}, \"nnz_tail\": {}, \"dense_tabled_slots\": {}, \
             \"hybrid_tabled_slots\": {}, \"dense_build_secs\": {:.9}, \
             \"hybrid_build_secs\": {:.9}, \"tail_build_speedup\": {:.2}, \
             \"dense_tokens_per_sec\": {:.0}, \"hybrid_tokens_per_sec\": {:.0}, \
             \"threshold_selects_hybrid\": {}}}{sep}\n",
            r.k,
            r.nnz_tail,
            r.dense_tabled_slots,
            r.hybrid_tabled_slots,
            r.dense_build_secs,
            r.hybrid_build_secs,
            r.tail_build_speedup,
            r.dense_tokens_per_sec,
            r.hybrid_tokens_per_sec,
            r.threshold_selects_hybrid,
        ));
    }
    body.push_str("  ],\n");
    body.push_str("  \"gibbs_vs_lightlda\": [\n");
    for (i, &(k, g, l)) in o1.iter().enumerate() {
        let sep = if i + 1 < o1.len() { "," } else { "" };
        body.push_str(&format!(
            "    {{\"k\": {k}, \"gibbs_tokens_per_sec\": {g:.0}, \
             \"lightlda_tokens_per_sec\": {l:.0}}}{sep}\n"
        ));
    }
    body.push_str("  ]\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn main() {
    let smoke = std::env::var("SMOKE").is_ok();
    let o1 = if smoke {
        println!("SMOKE=1: skipping the Gibbs-vs-LightLDA corpus sweeps");
        Vec::new()
    } else {
        o1_vs_ok()
    };
    let scaling = k_scaling(smoke);
    let json_path =
        std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_sampler.json".to_string());
    write_json(&json_path, smoke, &scaling, &o1);
}
