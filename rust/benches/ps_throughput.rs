//! Parameter-server micro-benchmarks: pull/push throughput vs shard
//! count and delta batch size, the cost of the exactly-once hand-shake
//! under message loss, the win from the asynchronous ticket API
//! (`pipeline_depth` 1 vs 8) with per-shard in-flight / queue-wait
//! stats, and the cost of durability (push throughput with the
//! write-ahead log on vs off).
//!
//! Environment knobs (used by CI):
//!
//! - `TRANSPORT=sim|tcp` — run over the in-process simulated transport
//!   (default) or real TCP loopback listeners;
//! - `SMOKE=1` — a fast regression path: tiny matrix, few shards, few
//!   rounds. Finishes in seconds while still exercising the full
//!   create/push/pull protocol over the selected transport;
//! - `PIPELINE_DEPTH=n` — the per-shard in-flight window used by the
//!   blocking-API sections (the pipelining section always compares
//!   depths 1 and 8);
//! - `LAYOUT=dense|sparse` — storage layout of the matrix used by the
//!   push/pull throughput sections (the sparse-vs-dense section always
//!   measures both);
//! - `BENCH_JSON=path` — where to write the machine-readable summary
//!   (default `BENCH_ps_throughput.json` in the working directory).

use glint_lda::net::FaultPlan;
use glint_lda::ps::client::{BigMatrix, CoordDeltas, PsClient};
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::messages::Layout;
use glint_lda::ps::server::ServerGroup;
use glint_lda::util::rng::Pcg64;
use glint_lda::util::timer::Stopwatch;

/// Workload dimensions, scaled down under SMOKE=1.
struct Dims {
    rows: u64,
    cols: u32,
    shard_counts: &'static [usize],
    batch_sizes: &'static [usize],
    pull_sizes: &'static [usize],
    big_batch: usize,
    rounds: usize,
    /// Batch size of one async fire-and-forget push.
    async_batch: usize,
    /// Rows per overlapped pull ticket.
    async_pull_rows: usize,
    /// Tickets issued per async measurement.
    async_rounds: usize,
}

const FULL: Dims = Dims {
    rows: 50_000,
    cols: 64,
    shard_counts: &[1, 2, 4, 8, 16, 30],
    batch_sizes: &[1_000, 10_000, 100_000, 500_000],
    pull_sizes: &[64, 512, 4096, 16384],
    big_batch: 100_000,
    rounds: 10,
    async_batch: 20_000,
    async_pull_rows: 4096,
    async_rounds: 48,
};

const SMOKE: Dims = Dims {
    rows: 2_000,
    cols: 16,
    shard_counts: &[1, 2],
    batch_sizes: &[500, 5_000],
    pull_sizes: &[64, 512],
    big_batch: 5_000,
    rounds: 2,
    async_batch: 500,
    async_pull_rows: 512,
    async_rounds: 24,
};

fn transport_mode() -> (TransportMode, &'static str) {
    match std::env::var("TRANSPORT").as_deref() {
        Ok("tcp") => (TransportMode::TcpLoopback, "tcp"),
        _ => (TransportMode::Sim, "sim"),
    }
}

fn is_smoke() -> bool {
    std::env::var("SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn env_pipeline_depth() -> usize {
    std::env::var("PIPELINE_DEPTH").ok().and_then(|v| v.parse().ok()).unwrap_or(4)
}

fn env_layout() -> (Layout, &'static str) {
    match std::env::var("LAYOUT") {
        Err(_) => (Layout::Dense, "dense"),
        // Fail loudly on a typo: a silent dense fallback would let the
        // CI sparse leg stop exercising the sparse path while staying
        // green.
        Ok(v) => match Layout::parse(&v) {
            Some(Layout::Sparse) => (Layout::Sparse, "sparse"),
            Some(Layout::Dense) => (Layout::Dense, "dense"),
            None => panic!("bad LAYOUT={v} (expected dense|sparse)"),
        },
    }
}

fn setup(
    dims: &Dims,
    shards: usize,
    mode: TransportMode,
    plan: FaultPlan,
    pipeline_depth: usize,
) -> (ServerGroup, PsClient, BigMatrix<i64>) {
    let cfg = PsConfig { transport: mode, pipeline_depth, ..PsConfig::with_shards(shards) };
    let group = ServerGroup::start(cfg.clone(), plan, 11);
    let client = PsClient::connect(&*group.transport(), cfg);
    let m = client
        .matrix_with_layout::<i64>(dims.rows, dims.cols, env_layout().0)
        .expect("matrix");
    (group, client, m)
}

fn make_deltas(dims: &Dims, batch: usize, seed: u64) -> CoordDeltas<i64> {
    let mut rng = Pcg64::new(seed);
    CoordDeltas {
        rows: (0..batch).map(|_| rng.below(dims.rows as usize) as u64).collect(),
        cols: (0..batch).map(|_| rng.below(dims.cols as usize) as u32).collect(),
        values: vec![1i64; batch],
    }
}

fn bench_push(dims: &Dims, m: &BigMatrix<i64>, batch: usize, rounds: usize) -> f64 {
    let deltas = make_deltas(dims, batch, 5);
    let sw = Stopwatch::new();
    for _ in 0..rounds {
        m.push_coords(&deltas).expect("push");
    }
    (batch * rounds) as f64 / sw.secs()
}

fn bench_pull(dims: &Dims, m: &BigMatrix<i64>, rows: usize, rounds: usize) -> f64 {
    let mut rng = Pcg64::new(6);
    let ids: Vec<u64> = (0..rows).map(|_| rng.below(dims.rows as usize) as u64).collect();
    let sw = Stopwatch::new();
    for _ in 0..rounds {
        let v = m.pull_rows(&ids).expect("pull");
        std::hint::black_box(v);
    }
    (rows * rounds) as f64 / sw.secs()
}

/// Fire-and-forget pushes riding the in-flight window, barriered once at
/// the end — the trainer's §3.3 update path.
fn bench_push_async(
    dims: &Dims,
    client: &PsClient,
    m: &BigMatrix<i64>,
    batch: usize,
    rounds: usize,
) -> f64 {
    let deltas = make_deltas(dims, batch, 7);
    let sw = Stopwatch::new();
    for _ in 0..rounds {
        let _ = m.push_coords_async(&deltas);
    }
    client.flush().expect("flush");
    (batch * rounds) as f64 / sw.secs()
}

/// Overlapped pulls: issue every ticket, then drain — the trainer's §3.4
/// prefetch path.
fn bench_pull_async(dims: &Dims, m: &BigMatrix<i64>, rows: usize, rounds: usize) -> f64 {
    let mut rng = Pcg64::new(8);
    let ids: Vec<u64> = (0..rows).map(|_| rng.below(dims.rows as usize) as u64).collect();
    let sw = Stopwatch::new();
    let tickets: Vec<_> = (0..rounds).map(|_| m.pull_rows_async(&ids)).collect();
    for t in tickets {
        std::hint::black_box(t.wait().expect("pull"));
    }
    (rows * rounds) as f64 / sw.secs()
}

/// One depth's measurements in the pipelining comparison.
struct PipelineResult {
    depth: usize,
    push_rate: f64,
    pull_rate: f64,
    max_in_flight: u64,
    avg_queue_wait_us: f64,
}

/// The sparse-vs-dense comparison at Zipfian row occupancy: reply bytes
/// on the wire and wall time for pulling the full matrix each way, plus
/// the server-side column-sum aggregation vs what it replaces.
struct LayoutCompareResult {
    rows: u64,
    cols: u32,
    /// Non-zero entries in the Zipf workload.
    pairs: u64,
    dense_pull_bytes: u64,
    sparse_pull_bytes: u64,
    dense_pull_secs: f64,
    sparse_pull_secs: f64,
    col_sums_bytes: u64,
    col_sums_secs: f64,
}

/// Populate `matrices` with an identical Zipf-occupancy workload
/// (row `r` holds `max(1, cols/(r+1))` non-zeros — the harmonic shape
/// of a frequency-ordered vocabulary) and return the pair count.
fn populate_zipf(dims: &Dims, matrices: &[&BigMatrix<i64>]) -> u64 {
    let mut deltas = CoordDeltas::default();
    let mut pairs = 0u64;
    let flush = |deltas: &mut CoordDeltas<i64>| {
        for m in matrices {
            m.push_coords(deltas).expect("zipf populate");
        }
        *deltas = CoordDeltas::default();
    };
    for r in 0..dims.rows {
        let nnz = (dims.cols as u64 / (r + 1)).max(1);
        for j in 0..nnz {
            let c = ((r + j) % dims.cols as u64) as u32;
            deltas.rows.push(r);
            deltas.cols.push(c);
            deltas.values.push((r % 7 + 1) as i64);
            pairs += 1;
        }
        if deltas.len() >= 100_000 {
            flush(&mut deltas);
        }
    }
    if !deltas.is_empty() {
        flush(&mut deltas);
    }
    pairs
}

/// Reply bytes received so far across all shards of `group`.
fn bytes_received(group: &ServerGroup) -> u64 {
    group.transport().stats().iter().map(|s| s.bytes_received()).sum()
}

fn bench_layout_compare(
    dims: &Dims,
    shards: usize,
    mode: TransportMode,
    depth: usize,
) -> LayoutCompareResult {
    let cfg =
        PsConfig { transport: mode, pipeline_depth: depth, ..PsConfig::with_shards(shards) };
    let group = ServerGroup::start(cfg.clone(), FaultPlan::reliable(), 13);
    let client = PsClient::connect(&*group.transport(), cfg);
    let dense_m = client
        .matrix_with_layout::<i64>(dims.rows, dims.cols, Layout::Dense)
        .expect("dense matrix");
    let sparse_m = client
        .matrix_with_layout::<i64>(dims.rows, dims.cols, Layout::Sparse)
        .expect("sparse matrix");
    let pairs = populate_zipf(dims, &[&dense_m, &sparse_m]);

    let all: Vec<u64> = (0..dims.rows).collect();
    let chunk = dims.async_pull_rows.max(1);

    let before = bytes_received(&group);
    let sw = Stopwatch::new();
    for ids in all.chunks(chunk) {
        std::hint::black_box(dense_m.pull_rows(ids).expect("dense pull"));
    }
    let dense_pull_secs = sw.secs();
    let dense_pull_bytes = bytes_received(&group) - before;

    let before = bytes_received(&group);
    let sw = Stopwatch::new();
    for ids in all.chunks(chunk) {
        std::hint::black_box(sparse_m.pull_sparse_rows(ids).expect("sparse pull"));
    }
    let sparse_pull_secs = sw.secs();
    let sparse_pull_bytes = bytes_received(&group) - before;

    // The aggregation the trainer runs each iteration: one K-length
    // vector per shard, instead of pulling every row to sum client-side.
    let before = bytes_received(&group);
    let sw = Stopwatch::new();
    std::hint::black_box(sparse_m.pull_col_sums().expect("col sums"));
    let col_sums_secs = sw.secs();
    let col_sums_bytes = bytes_received(&group) - before;

    LayoutCompareResult {
        rows: dims.rows,
        cols: dims.cols,
        pairs,
        dense_pull_bytes,
        sparse_pull_bytes,
        dense_pull_secs,
        sparse_pull_secs,
        col_sums_bytes,
        col_sums_secs,
    }
}

/// WAL-on vs WAL-off push throughput: what durable group commit costs
/// on the synchronous and fire-and-forget push paths, plus the log's
/// own accounting from `ShardInfo`.
struct WalCompareResult {
    off_push_rate: f64,
    on_push_rate: f64,
    off_async_rate: f64,
    on_async_rate: f64,
    wal_records: u64,
    wal_bytes: u64,
    wal_commit_batches: u64,
}

fn bench_wal_compare(
    dims: &Dims,
    shards: usize,
    mode: TransportMode,
    depth: usize,
) -> WalCompareResult {
    let run = |wal_dir: Option<std::path::PathBuf>| {
        let cfg = PsConfig {
            transport: mode.clone(),
            pipeline_depth: depth,
            wal_dir,
            ..PsConfig::with_shards(shards)
        };
        let group = ServerGroup::start(cfg.clone(), FaultPlan::reliable(), 17);
        let client = PsClient::connect(&*group.transport(), cfg);
        let m = client
            .matrix_with_layout::<i64>(dims.rows, dims.cols, Layout::Dense)
            .expect("wal bench matrix");
        let push_rate = bench_push(dims, &m, dims.async_batch, dims.rounds);
        let async_rate = bench_push_async(dims, &client, &m, dims.async_batch, dims.rounds);
        let infos = client.shard_infos().expect("shard infos");
        (
            push_rate,
            async_rate,
            infos.iter().map(|i| i.wal_records).sum(),
            infos.iter().map(|i| i.wal_bytes).sum(),
            infos.iter().map(|i| i.wal_commit_batches).sum(),
        )
    };
    let (off_push_rate, off_async_rate, ..) = run(None);
    let dir = std::env::temp_dir().join(format!("glint-bench-wal-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (on_push_rate, on_async_rate, wal_records, wal_bytes, wal_commit_batches) =
        run(Some(dir.clone()));
    let _ = std::fs::remove_dir_all(&dir);
    WalCompareResult {
        off_push_rate,
        on_push_rate,
        off_async_rate,
        on_async_rate,
        wal_records,
        wal_bytes,
        wal_commit_batches,
    }
}

fn json_escape_free(s: &str) -> &str {
    // Labels written into the JSON artifact are static identifiers.
    debug_assert!(!s.contains('"') && !s.contains('\\'));
    s
}

#[allow(clippy::too_many_arguments)]
fn write_json(
    path: &str,
    transport: &str,
    smoke: bool,
    depth_env: usize,
    layout_env: &str,
    results: &[PipelineResult],
    layout: &LayoutCompareResult,
    wal: &WalCompareResult,
) {
    let mut body = String::new();
    body.push_str("{\n");
    body.push_str("  \"bench\": \"ps_throughput\",\n");
    body.push_str("  \"source\": \"measured\",\n");
    body.push_str(&format!("  \"transport\": \"{}\",\n", json_escape_free(transport)));
    body.push_str(&format!("  \"smoke\": {smoke},\n"));
    body.push_str(&format!("  \"env_pipeline_depth\": {depth_env},\n"));
    body.push_str(&format!("  \"env_layout\": \"{}\",\n", json_escape_free(layout_env)));
    body.push_str("  \"pipeline\": [\n");
    for (i, r) in results.iter().enumerate() {
        body.push_str(&format!(
            "    {{\"depth\": {}, \"push_deltas_per_sec\": {:.1}, \
             \"pull_rows_per_sec\": {:.1}, \"max_in_flight\": {}, \
             \"avg_queue_wait_us\": {:.2}}}{}\n",
            r.depth,
            r.push_rate,
            r.pull_rate,
            r.max_in_flight,
            r.avg_queue_wait_us,
            if i + 1 < results.len() { "," } else { "" },
        ));
    }
    body.push_str("  ],\n");
    let ratio = if layout.sparse_pull_bytes > 0 {
        layout.dense_pull_bytes as f64 / layout.sparse_pull_bytes as f64
    } else {
        0.0
    };
    body.push_str("  \"zipf_layout_compare\": {\n");
    body.push_str(&format!(
        "    \"rows\": {}, \"cols\": {}, \"pairs\": {},\n",
        layout.rows, layout.cols, layout.pairs
    ));
    body.push_str(&format!(
        "    \"dense_pull_bytes\": {}, \"sparse_pull_bytes\": {}, \
         \"dense_over_sparse_bytes\": {:.2},\n",
        layout.dense_pull_bytes, layout.sparse_pull_bytes, ratio
    ));
    body.push_str(&format!(
        "    \"dense_pull_secs\": {:.4}, \"sparse_pull_secs\": {:.4},\n",
        layout.dense_pull_secs, layout.sparse_pull_secs
    ));
    body.push_str(&format!(
        "    \"col_sums_bytes\": {}, \"col_sums_secs\": {:.6}\n",
        layout.col_sums_bytes, layout.col_sums_secs
    ));
    body.push_str("  },\n");
    let on_over_off = |on: f64, off: f64| if off > 0.0 { on / off } else { 0.0 };
    body.push_str("  \"wal_compare\": {\n");
    body.push_str(&format!(
        "    \"push_deltas_per_sec_wal_off\": {:.1}, \"push_deltas_per_sec_wal_on\": {:.1}, \
         \"push_wal_on_over_off\": {:.3},\n",
        wal.off_push_rate,
        wal.on_push_rate,
        on_over_off(wal.on_push_rate, wal.off_push_rate)
    ));
    body.push_str(&format!(
        "    \"async_push_deltas_per_sec_wal_off\": {:.1}, \
         \"async_push_deltas_per_sec_wal_on\": {:.1}, \"async_push_wal_on_over_off\": {:.3},\n",
        wal.off_async_rate,
        wal.on_async_rate,
        on_over_off(wal.on_async_rate, wal.off_async_rate)
    ));
    body.push_str(&format!(
        "    \"wal_records\": {}, \"wal_bytes\": {}, \"wal_commit_batches\": {}\n",
        wal.wal_records, wal.wal_bytes, wal.wal_commit_batches
    ));
    body.push_str("  }\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn main() {
    let (mode, label) = transport_mode();
    let smoke = is_smoke();
    let depth_env = env_pipeline_depth();
    let (_, layout_label) = env_layout();
    let dims = if smoke { &SMOKE } else { &FULL };
    println!(
        "== ps_throughput: transport={label}, smoke={smoke}, pipeline_depth={depth_env}, \
         layout={layout_label} =="
    );

    println!("== push throughput (deltas/s) vs shards, batch={} ==", dims.big_batch);
    for &shards in dims.shard_counts {
        let (_g, _c, m) = setup(dims, shards, mode.clone(), FaultPlan::reliable(), depth_env);
        let rate = bench_push(dims, &m, dims.big_batch, dims.rounds);
        println!("  shards {shards:>3}: {rate:>12.0} deltas/s");
    }

    let mid_shards = if smoke { 2 } else { 4 };
    println!("== push throughput vs batch size ({mid_shards} shards) ==");
    let (_g, _c, m) = setup(dims, mid_shards, mode.clone(), FaultPlan::reliable(), depth_env);
    for &batch in dims.batch_sizes {
        let rate = bench_push(dims, &m, batch, (dims.big_batch * 10 / batch).max(2));
        println!("  batch {batch:>7}: {rate:>12.0} deltas/s");
    }

    println!(
        "== pull throughput (rows/s, K={}) vs rows per request ==",
        dims.cols
    );
    for &rows in dims.pull_sizes {
        let rate = bench_pull(dims, &m, rows, (dims.big_batch / rows).max(2));
        println!("  rows {rows:>6}: {rate:>12.0} rows/s");
    }

    // The headline comparison: the same async workload (fire-and-forget
    // pushes + overlapped pulls) through a serialized window (depth 1)
    // vs a pipelined one (depth 8).
    println!(
        "== async pipelining, depth 1 vs 8 ({mid_shards} shards, batch={}, {} tickets) ==",
        dims.async_batch, dims.async_rounds
    );
    let mut results: Vec<PipelineResult> = Vec::new();
    for depth in [1usize, 8] {
        let (g, client, m) = setup(dims, mid_shards, mode.clone(), FaultPlan::reliable(), depth);
        let push_rate = bench_push_async(dims, &client, &m, dims.async_batch, dims.async_rounds);
        let pull_rate = bench_pull_async(dims, &m, dims.async_pull_rows, dims.async_rounds);
        let stats = g.transport().stats();
        let max_in_flight = stats.iter().map(|s| s.max_in_flight()).max().unwrap_or(0);
        let dispatched: u64 = stats.iter().map(|s| s.dispatched_ops()).sum();
        let wait_sum: f64 = stats
            .iter()
            .map(|s| s.avg_queue_wait().as_secs_f64() * s.dispatched_ops() as f64)
            .sum();
        let avg_queue_wait_us =
            if dispatched > 0 { wait_sum / dispatched as f64 * 1e6 } else { 0.0 };
        println!(
            "  depth {depth}: push {push_rate:>12.0} deltas/s, pull {pull_rate:>12.0} rows/s, \
             max in-flight {max_in_flight}, avg queue wait {avg_queue_wait_us:.1} us"
        );
        results.push(PipelineResult {
            depth,
            push_rate,
            pull_rate,
            max_in_flight,
            avg_queue_wait_us,
        });
    }
    if let [d1, d8] = &results[..] {
        println!(
            "  speedup depth8/depth1: push {:.2}x, pull {:.2}x",
            d8.push_rate / d1.push_rate,
            d8.pull_rate / d1.pull_rate
        );
    }

    // The tentpole comparison: how many reply bytes (and how long) a
    // full-model pull costs dense vs sparse at Zipfian row occupancy,
    // plus the server-side column-sum aggregation the trainer now uses
    // for the global topic vector.
    println!(
        "== sparse vs dense at Zipf occupancy ({mid_shards} shards, {}x{}) ==",
        dims.rows, dims.cols
    );
    let layout_result = bench_layout_compare(dims, mid_shards, mode.clone(), depth_env);
    println!(
        "  workload: {} non-zero pairs ({:.2}% fill)",
        layout_result.pairs,
        100.0 * layout_result.pairs as f64
            / (layout_result.rows as f64 * layout_result.cols as f64)
    );
    println!(
        "  dense  pull: {:>12} reply bytes, {:.3}s",
        layout_result.dense_pull_bytes, layout_result.dense_pull_secs
    );
    println!(
        "  sparse pull: {:>12} reply bytes, {:.3}s ({:.1}x fewer bytes)",
        layout_result.sparse_pull_bytes,
        layout_result.sparse_pull_secs,
        layout_result.dense_pull_bytes as f64 / layout_result.sparse_pull_bytes.max(1) as f64
    );
    println!(
        "  col sums   : {:>12} reply bytes, {:.6}s (vs pulling the matrix to sum it)",
        layout_result.col_sums_bytes, layout_result.col_sums_secs
    );

    // What durability costs: the same push workloads against shards
    // with and without a write-ahead log (group commit amortizes the
    // fsyncs, so the async path should hide most of it).
    println!(
        "== WAL on vs off ({mid_shards} shards, batch={}, {} rounds) ==",
        dims.async_batch, dims.rounds
    );
    let wal_result = bench_wal_compare(dims, mid_shards, mode.clone(), depth_env);
    println!(
        "  sync  push: {:>12.0} deltas/s off, {:>12.0} deltas/s on ({:.2}x)",
        wal_result.off_push_rate,
        wal_result.on_push_rate,
        wal_result.on_push_rate / wal_result.off_push_rate.max(1e-9)
    );
    println!(
        "  async push: {:>12.0} deltas/s off, {:>12.0} deltas/s on ({:.2}x)",
        wal_result.off_async_rate,
        wal_result.on_async_rate,
        wal_result.on_async_rate / wal_result.off_async_rate.max(1e-9)
    );
    println!(
        "  wal: {} records, {} bytes, {} commit batches",
        wal_result.wal_records, wal_result.wal_bytes, wal_result.wal_commit_batches
    );

    if mode == TransportMode::Sim {
        println!(
            "== exactly-once overhead under loss ({mid_shards} shards, batch={}) ==",
            dims.big_batch
        );
        for (label, plan) in [
            ("reliable", FaultPlan::reliable()),
            ("1% loss", FaultPlan::lossy(0.01, 0.0)),
            ("5% loss", FaultPlan::lossy(0.05, 0.01)),
        ] {
            let (_g, _c, m) = setup(dims, mid_shards, mode.clone(), plan, depth_env);
            let rate = bench_push(dims, &m, dims.big_batch, dims.rounds.min(5));
            println!("  {label:>9}: {rate:>12.0} deltas/s");
        }
    } else {
        println!("== fault-injection section skipped (sim-only) ==");
    }

    let json_path = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_ps_throughput.json".to_string());
    write_json(
        &json_path,
        label,
        smoke,
        depth_env,
        layout_label,
        &results,
        &layout_result,
        &wal_result,
    );
}
