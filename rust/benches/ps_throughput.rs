//! Parameter-server micro-benchmarks: pull/push throughput vs shard
//! count and delta batch size, and the cost of the exactly-once
//! hand-shake under message loss.

use glint_lda::net::FaultPlan;
use glint_lda::ps::client::{BigMatrix, CoordDeltas, PsClient};
use glint_lda::ps::config::PsConfig;
use glint_lda::ps::server::ServerGroup;
use glint_lda::util::rng::Pcg64;
use glint_lda::util::timer::Stopwatch;

fn setup(shards: usize, plan: FaultPlan) -> (ServerGroup, BigMatrix<i64>) {
    let cfg = PsConfig::with_shards(shards);
    let group = ServerGroup::start(cfg.clone(), plan, 11);
    let client = PsClient::connect(&group.transport(), cfg);
    let m = client.matrix::<i64>(50_000, 64).expect("matrix");
    (group, m)
}

fn bench_push(m: &BigMatrix<i64>, batch: usize, rounds: usize) -> f64 {
    let mut rng = Pcg64::new(5);
    let deltas = CoordDeltas {
        rows: (0..batch).map(|_| rng.below(50_000) as u64).collect(),
        cols: (0..batch).map(|_| rng.below(64) as u32).collect(),
        values: vec![1i64; batch],
    };
    let sw = Stopwatch::new();
    for _ in 0..rounds {
        m.push_coords(&deltas).expect("push");
    }
    (batch * rounds) as f64 / sw.secs()
}

fn bench_pull(m: &BigMatrix<i64>, rows: usize, rounds: usize) -> f64 {
    let mut rng = Pcg64::new(6);
    let ids: Vec<u64> = (0..rows).map(|_| rng.below(50_000) as u64).collect();
    let sw = Stopwatch::new();
    for _ in 0..rounds {
        let v = m.pull_rows(&ids).expect("pull");
        std::hint::black_box(v);
    }
    (rows * rounds) as f64 / sw.secs()
}

fn main() {
    println!("== push throughput (deltas/s) vs shards, batch=100k ==");
    for shards in [1, 2, 4, 8, 16, 30] {
        let (_g, m) = setup(shards, FaultPlan::reliable());
        let rate = bench_push(&m, 100_000, 10);
        println!("  shards {shards:>3}: {rate:>12.0} deltas/s");
    }
    println!("== push throughput vs batch size (4 shards) ==");
    let (_g, m) = setup(4, FaultPlan::reliable());
    for batch in [1_000, 10_000, 100_000, 500_000] {
        let rate = bench_push(&m, batch, (1_000_000 / batch).max(2));
        println!("  batch {batch:>7}: {rate:>12.0} deltas/s");
    }
    println!("== pull throughput (rows/s, K=64) vs rows per request ==");
    for rows in [64, 512, 4096, 16384] {
        let rate = bench_pull(&m, rows, (100_000 / rows).max(2));
        println!("  rows {rows:>6}: {rate:>12.0} rows/s");
    }
    println!("== exactly-once overhead under loss (4 shards, batch=100k) ==");
    for (label, plan) in [
        ("reliable", FaultPlan::reliable()),
        ("1% loss", FaultPlan::lossy(0.01, 0.0)),
        ("5% loss", FaultPlan::lossy(0.05, 0.01)),
    ] {
        let (_g, m) = setup(4, plan);
        let rate = bench_push(&m, 100_000, 5);
        println!("  {label:>9}: {rate:>12.0} deltas/s");
    }
}
