//! Parameter-server micro-benchmarks: pull/push throughput vs shard
//! count and delta batch size, and the cost of the exactly-once
//! hand-shake under message loss.
//!
//! Environment knobs (used by CI):
//!
//! - `TRANSPORT=sim|tcp` — run over the in-process simulated transport
//!   (default) or real TCP loopback listeners;
//! - `SMOKE=1` — a fast regression path: tiny matrix, few shards, few
//!   rounds. Finishes in seconds while still exercising the full
//!   create/push/pull protocol over the selected transport.

use glint_lda::net::FaultPlan;
use glint_lda::ps::client::{BigMatrix, CoordDeltas, PsClient};
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::server::ServerGroup;
use glint_lda::util::rng::Pcg64;
use glint_lda::util::timer::Stopwatch;

/// Workload dimensions, scaled down under SMOKE=1.
struct Dims {
    rows: u64,
    cols: u32,
    shard_counts: &'static [usize],
    batch_sizes: &'static [usize],
    pull_sizes: &'static [usize],
    big_batch: usize,
    rounds: usize,
}

const FULL: Dims = Dims {
    rows: 50_000,
    cols: 64,
    shard_counts: &[1, 2, 4, 8, 16, 30],
    batch_sizes: &[1_000, 10_000, 100_000, 500_000],
    pull_sizes: &[64, 512, 4096, 16384],
    big_batch: 100_000,
    rounds: 10,
};

const SMOKE: Dims = Dims {
    rows: 2_000,
    cols: 16,
    shard_counts: &[1, 2],
    batch_sizes: &[500, 5_000],
    pull_sizes: &[64, 512],
    big_batch: 5_000,
    rounds: 2,
};

fn transport_mode() -> (TransportMode, &'static str) {
    match std::env::var("TRANSPORT").as_deref() {
        Ok("tcp") => (TransportMode::TcpLoopback, "tcp"),
        _ => (TransportMode::Sim, "sim"),
    }
}

fn is_smoke() -> bool {
    std::env::var("SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn setup(
    dims: &Dims,
    shards: usize,
    mode: TransportMode,
    plan: FaultPlan,
) -> (ServerGroup, BigMatrix<i64>) {
    let cfg = PsConfig { transport: mode, ..PsConfig::with_shards(shards) };
    let group = ServerGroup::start(cfg.clone(), plan, 11);
    let client = PsClient::connect(&*group.transport(), cfg);
    let m = client.matrix::<i64>(dims.rows, dims.cols).expect("matrix");
    (group, m)
}

fn bench_push(dims: &Dims, m: &BigMatrix<i64>, batch: usize, rounds: usize) -> f64 {
    let mut rng = Pcg64::new(5);
    let deltas = CoordDeltas {
        rows: (0..batch).map(|_| rng.below(dims.rows as usize) as u64).collect(),
        cols: (0..batch).map(|_| rng.below(dims.cols as usize) as u32).collect(),
        values: vec![1i64; batch],
    };
    let sw = Stopwatch::new();
    for _ in 0..rounds {
        m.push_coords(&deltas).expect("push");
    }
    (batch * rounds) as f64 / sw.secs()
}

fn bench_pull(dims: &Dims, m: &BigMatrix<i64>, rows: usize, rounds: usize) -> f64 {
    let mut rng = Pcg64::new(6);
    let ids: Vec<u64> = (0..rows).map(|_| rng.below(dims.rows as usize) as u64).collect();
    let sw = Stopwatch::new();
    for _ in 0..rounds {
        let v = m.pull_rows(&ids).expect("pull");
        std::hint::black_box(v);
    }
    (rows * rounds) as f64 / sw.secs()
}

fn main() {
    let (mode, label) = transport_mode();
    let smoke = is_smoke();
    let dims = if smoke { &SMOKE } else { &FULL };
    println!("== ps_throughput: transport={label}, smoke={smoke} ==");

    println!("== push throughput (deltas/s) vs shards, batch={} ==", dims.big_batch);
    for &shards in dims.shard_counts {
        let (_g, m) = setup(dims, shards, mode.clone(), FaultPlan::reliable());
        let rate = bench_push(dims, &m, dims.big_batch, dims.rounds);
        println!("  shards {shards:>3}: {rate:>12.0} deltas/s");
    }

    let mid_shards = if smoke { 2 } else { 4 };
    println!("== push throughput vs batch size ({mid_shards} shards) ==");
    let (_g, m) = setup(dims, mid_shards, mode.clone(), FaultPlan::reliable());
    for &batch in dims.batch_sizes {
        let rate = bench_push(dims, &m, batch, (dims.big_batch * 10 / batch).max(2));
        println!("  batch {batch:>7}: {rate:>12.0} deltas/s");
    }

    println!(
        "== pull throughput (rows/s, K={}) vs rows per request ==",
        dims.cols
    );
    for &rows in dims.pull_sizes {
        let rate = bench_pull(dims, &m, rows, (dims.big_batch / rows).max(2));
        println!("  rows {rows:>6}: {rate:>12.0} rows/s");
    }

    if mode == TransportMode::Sim {
        println!(
            "== exactly-once overhead under loss ({mid_shards} shards, batch={}) ==",
            dims.big_batch
        );
        for (label, plan) in [
            ("reliable", FaultPlan::reliable()),
            ("1% loss", FaultPlan::lossy(0.01, 0.0)),
            ("5% loss", FaultPlan::lossy(0.05, 0.01)),
        ] {
            let (_g, m) = setup(dims, mid_shards, mode.clone(), plan);
            let rate = bench_push(dims, &m, dims.big_batch, dims.rounds.min(5));
            println!("  {label:>9}: {rate:>12.0} deltas/s");
        }
    } else {
        println!("== fault-injection section skipped (sim-only) ==");
    }
}
