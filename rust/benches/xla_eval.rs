//! XLA/Pallas evaluation-path benchmark: rust evaluator vs the
//! AOT-compiled `perplexity` graph (Pallas kernel) vs the `_ref`
//! (pure-jnp lowering) artifact — the L1/L2 perf ablation.

use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::eval::perplexity::{log_likelihood, TopicModel};
use glint_lda::eval::xla::xla_log_likelihood;
use glint_lda::lda::gibbs::LocalModel;
use glint_lda::lda::hyper::LdaHyper;
use glint_lda::runtime::engine::Engine;
use glint_lda::util::rng::Pcg64;
use glint_lda::util::timer::Stopwatch;

fn main() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(engine) = Engine::new(&dir) else {
        println!("artifacts missing — run `make artifacts`; skipping xla_eval bench");
        return;
    };
    let corpus = generate(&SynthConfig {
        num_docs: 1000,
        vocab_size: 8000,
        num_topics: 32,
        avg_doc_len: 80.0,
        ..Default::default()
    });
    let k = 128u32;
    let mut m = LocalModel::init_random(&corpus, k, LdaHyper::default_for(k as usize), 1);
    let mut rng = Pcg64::new(2);
    glint_lda::lda::gibbs::sweep(&mut m, &corpus, &mut rng);
    let tm = TopicModel::from_local(&m);
    let tokens = corpus.num_tokens();

    // Rust scalar evaluator.
    let sw = Stopwatch::new();
    let (ll_rust, _) = log_likelihood(&tm, &corpus, &m.doc_counts);
    let t_rust = sw.secs();
    println!(
        "rust evaluator:        {t_rust:.3}s ({:.1} M tokens/s), ll={ll_rust:.1}",
        tokens as f64 / t_rust / 1e6
    );

    // XLA with Pallas kernel.
    let sw = Stopwatch::new();
    let (ll_xla, _) = xla_log_likelihood(&engine, &tm, &corpus, &m.doc_counts).unwrap();
    let t_xla = sw.secs();
    println!(
        "xla (pallas kernel):   {t_xla:.3}s ({:.1} M tokens/s), ll={ll_xla:.1}",
        tokens as f64 / t_xla / 1e6
    );
    // Second run: executable already compiled (steady-state cost).
    let sw = Stopwatch::new();
    let (_, _) = xla_log_likelihood(&engine, &tm, &corpus, &m.doc_counts).unwrap();
    let t_xla2 = sw.secs();
    println!(
        "xla (pallas, warm):    {t_xla2:.3}s ({:.1} M tokens/s)",
        tokens as f64 / t_xla2 / 1e6
    );

    let rel = ((ll_rust - ll_xla) / ll_rust).abs();
    println!("agreement: rel diff {rel:.2e}");
    assert!(rel < 1e-4);
}
