//! Regenerates the paper's Table 1 (perplexity / runtime / shuffle write
//! for ours vs Spark EM vs Spark Online over size and K sweeps).
//!
//! Scale with the env var `GLINT_BENCH_SCALE` (default 0.35 keeps
//! `cargo bench` under a few minutes; the EXPERIMENTS.md numbers use 1.0
//! via `glint-lda table1 --scale 1.0`).

use glint_lda::experiments::table1;

fn main() {
    glint_lda::util::logger::set_level_str("info");
    let scale: f64 = std::env::var("GLINT_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.35);
    let cfg = table1::Table1Config {
        scale,
        iterations: 15,
        ..table1::Table1Config::default()
    };
    let report = table1::run(&cfg).expect("table1 run");
    println!("{}", table1::render_paper_style(&report));
    println!("csv:\n{}", report.to_csv());
    assert!(
        table1::perplexity_parity(&report, 0.5),
        "perplexity parity violated"
    );
}
