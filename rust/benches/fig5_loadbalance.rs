//! Regenerates the paper's Figure 5: expected per-machine request share
//! over 30 machines for cyclic/ordered, cyclic/shuffled and
//! range/ordered layouts, validated against measured traffic from a real
//! training run over the parameter server.

use glint_lda::experiments::fig5;

fn main() {
    glint_lda::util::logger::set_level_str("info");
    let scale: f64 = std::env::var("GLINT_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5);
    let r = fig5::run(&fig5::Fig5Config { scale, machines: 30, measure: true })
        .expect("fig5 run");
    println!("{}", r.report.to_table());
    println!("imbalance (max/mean, 1.0 = perfect):");
    for (name, f) in &r.imbalance {
        println!("  {name:>18}: {f:.3}");
    }
    let get = |n: &str| r.imbalance.iter().find(|(x, _)| x == n).unwrap().1;
    assert!(get("cyclic_ordered") < get("cyclic_shuffled"));
    assert!(get("cyclic_ordered") < get("range_ordered"));
}
