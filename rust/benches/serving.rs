//! Serve-model latency/throughput benchmark: the full serving topology
//! on loopback — two TCP parameter-server shards, a brief LightLDA
//! training run to freeze a model onto them, one serving replica
//! attached read-mostly by matrix id, and N concurrent [`InferClient`]s
//! firing single-document inference requests so the replica's batching
//! window actually coalesces traffic from different connections.
//!
//! Reported: per-request latency percentiles (p50/p99) and aggregate
//! QPS across all clients, plus the replica's own counters (cache hits,
//! coalesced sparse pulls, average docs per batch).
//!
//! Environment knobs (used by CI):
//!
//! - `SMOKE=1` — tiny corpus, 3 training iterations, 4 clients; finishes
//!   in seconds while exercising train → freeze → attach → serve →
//!   concurrent inference end to end;
//! - `CLIENTS=n` — override the concurrent client count;
//! - `BENCH_JSON=path` — where to write the machine-readable summary
//!   (default `BENCH_serving.json` in the working directory).

use std::sync::Arc;

use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::lda::infer::{FoldInBudget, InferConfig, InferEngine};
use glint_lda::lda::sweep::SamplerParams;
use glint_lda::lda::trainer::{TrainConfig, Trainer};
use glint_lda::net::infer::ServeStats;
use glint_lda::net::tcp::TcpTransport;
use glint_lda::ps::client::PsClient;
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::messages::Layout;
use glint_lda::ps::partition::PartitionScheme;
use glint_lda::ps::server::TcpShardServer;
use glint_lda::serving::{InferClient, InferServer, DEFAULT_BATCH_WINDOW};
use glint_lda::util::rng::Pcg64;
use glint_lda::util::timer::Stopwatch;

/// Parameter-server shards backing the frozen model.
const SHARDS: usize = 2;

/// Workload dimensions, scaled down under SMOKE=1.
struct Dims {
    num_docs: usize,
    vocab_size: u32,
    gen_topics: usize,
    avg_doc_len: f64,
    num_topics: u32,
    iterations: u32,
    clients: usize,
    requests_per_client: usize,
}

const FULL: Dims = Dims {
    num_docs: 4_000,
    vocab_size: 4_000,
    gen_topics: 20,
    avg_doc_len: 60.0,
    num_topics: 40,
    iterations: 15,
    clients: 8,
    requests_per_client: 250,
};

const SMOKE: Dims = Dims {
    num_docs: 360,
    vocab_size: 800,
    gen_topics: 8,
    avg_doc_len: 45.0,
    num_topics: 10,
    iterations: 3,
    clients: 4,
    requests_per_client: 40,
};

fn is_smoke() -> bool {
    std::env::var("SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn env_clients(default: usize) -> usize {
    std::env::var("CLIENTS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// `p`-th percentile (0..=1) of an ascending-sorted sample.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[allow(clippy::too_many_arguments)]
fn write_json(
    path: &str,
    smoke: bool,
    clients: usize,
    requests_per_client: usize,
    unique_docs: usize,
    p50_ms: f64,
    p99_ms: f64,
    qps: f64,
    wall_secs: f64,
    stats: &ServeStats,
) {
    let requests = (clients * requests_per_client) as u64;
    let avg_batch_docs =
        if stats.batches > 0 { stats.docs as f64 / stats.batches as f64 } else { 0.0 };
    let mut body = String::new();
    body.push_str("{\n");
    body.push_str("  \"bench\": \"serving\",\n");
    body.push_str("  \"source\": \"measured\",\n");
    body.push_str(&format!("  \"smoke\": {smoke},\n"));
    body.push_str(&format!("  \"shards\": {SHARDS},\n"));
    body.push_str(&format!("  \"clients\": {clients},\n"));
    body.push_str(&format!("  \"requests_per_client\": {requests_per_client},\n"));
    body.push_str(&format!("  \"requests\": {requests},\n"));
    body.push_str(&format!("  \"unique_docs\": {unique_docs},\n"));
    body.push_str(&format!(
        "  \"batch_window_ms\": {:.3},\n",
        DEFAULT_BATCH_WINDOW.as_secs_f64() * 1e3
    ));
    body.push_str(&format!("  \"p50_latency_ms\": {p50_ms:.3},\n"));
    body.push_str(&format!("  \"p99_latency_ms\": {p99_ms:.3},\n"));
    body.push_str(&format!("  \"qps\": {qps:.1},\n"));
    body.push_str(&format!("  \"wall_secs\": {wall_secs:.3},\n"));
    body.push_str("  \"server\": {\n");
    body.push_str(&format!("    \"requests\": {},\n", stats.requests));
    body.push_str(&format!("    \"docs\": {},\n", stats.docs));
    body.push_str(&format!("    \"cache_hits\": {},\n", stats.cache_hits));
    body.push_str(&format!("    \"words_pulled\": {},\n", stats.words_pulled));
    body.push_str(&format!("    \"sparse_pulls\": {},\n", stats.sparse_pulls));
    body.push_str(&format!("    \"batches\": {},\n", stats.batches));
    body.push_str(&format!("    \"avg_batch_docs\": {avg_batch_docs:.2}\n"));
    body.push_str("  }\n}\n");
    match std::fs::write(path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn main() {
    let smoke = is_smoke();
    let dims = if smoke { &SMOKE } else { &FULL };
    let clients = env_clients(dims.clients);
    println!("== serving: smoke={smoke}, shards={SHARDS}, clients={clients} ==");

    // Corpus: train on one split, serve the held-out split as "unseen"
    // documents (they never entered the frozen counts).
    let corpus = generate(&SynthConfig {
        num_docs: dims.num_docs,
        vocab_size: dims.vocab_size,
        num_topics: dims.gen_topics,
        avg_doc_len: dims.avg_doc_len,
        seed: 0x5e21_2026,
        ..Default::default()
    });
    let (train, test) = corpus.split_holdout(5);

    // Two real TCP shard servers, the `glint-lda serve` code path.
    let binds: Vec<std::net::SocketAddr> =
        (0..SHARDS).map(|_| "127.0.0.1:0".parse().unwrap()).collect();
    let shard_server =
        TcpShardServer::bind(PsConfig::with_shards(SHARDS), 0, &binds).expect("bind shards");
    let shard_addrs: Vec<String> =
        shard_server.addrs().iter().map(|a| a.to_string()).collect();

    // Brief training run to freeze a model onto the shards.
    let cfg = TrainConfig {
        num_topics: dims.num_topics,
        iterations: dims.iterations,
        workers: 3,
        shards: SHARDS,
        sampler: SamplerParams {
            block_words: 512,
            buffer_cap: 20_000,
            dense_top_words: 100,
            ..Default::default()
        },
        transport: TransportMode::Connect(shard_addrs.clone()),
        ..Default::default()
    };
    let hyper = cfg.hyper();
    let sw = Stopwatch::new();
    let mut trainer = Trainer::new(cfg, &train).expect("trainer");
    trainer.run(&train).expect("train");
    println!(
        "== trained {} iterations (K={}, V={}) in {:.1}s ==",
        dims.iterations,
        dims.num_topics,
        train.vocab_size,
        sw.secs()
    );

    // Serving replica: its own read-mostly PS connection, attached to
    // the frozen table by the trainer's matrix id.
    let serve_cfg =
        PsConfig::serving(SHARDS, PartitionScheme::Cyclic, TransportMode::Connect(shard_addrs));
    let transport = TcpTransport::connect(shard_server.addrs());
    let ps_client = PsClient::connect(&transport, serve_cfg);
    let engine = InferEngine::attach(
        &ps_client,
        trainer.matrix_id(),
        train.vocab_size,
        dims.num_topics,
        Layout::Sparse,
        hyper,
        InferConfig { budget: FoldInBudget { sweeps: 5, mh_steps: 2 }, ..Default::default() },
    )
    .expect("attach");
    let replica =
        InferServer::start(engine, "127.0.0.1:0", DEFAULT_BATCH_WINDOW).expect("replica");
    let replica_addr = replica.addr().to_string();

    // Unseen-document pool shared by every client.
    let pool: Arc<Vec<Vec<u32>>> = Arc::new(
        test.docs.iter().map(|d| d.tokens.clone()).filter(|t| !t.is_empty()).collect(),
    );
    assert!(!pool.is_empty(), "held-out pool must not be empty");

    println!(
        "== {clients} concurrent clients x {} single-doc requests ({} unique docs) ==",
        dims.requests_per_client,
        pool.len()
    );
    let wall = Stopwatch::new();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let pool = Arc::clone(&pool);
            let addr = replica_addr.clone();
            let requests = dims.requests_per_client;
            std::thread::spawn(move || {
                let client = InferClient::connect(&addr).expect("connect replica");
                let mut rng = Pcg64::new(0xc11e47 + c as u64);
                let mut latencies = Vec::with_capacity(requests);
                for _ in 0..requests {
                    let doc = &pool[rng.below(pool.len())];
                    let sw = Stopwatch::new();
                    let topics = client.infer_one(doc).expect("infer");
                    latencies.push(sw.secs());
                    let answered: usize = topics.iter().map(|&(_, n)| n as usize).sum();
                    assert_eq!(answered, doc.len(), "topic counts must sum to doc length");
                }
                latencies
            })
        })
        .collect();
    let mut latencies: Vec<f64> = handles
        .into_iter()
        .flat_map(|h| h.join().expect("client thread"))
        .collect();
    let wall_secs = wall.secs();
    latencies.sort_by(f64::total_cmp);

    let total = clients * dims.requests_per_client;
    let p50_ms = percentile(&latencies, 0.50) * 1e3;
    let p99_ms = percentile(&latencies, 0.99) * 1e3;
    let qps = total as f64 / wall_secs;
    println!(
        "  p50 {p50_ms:.2} ms, p99 {p99_ms:.2} ms, {qps:.0} req/s ({total} requests in \
         {wall_secs:.2}s)"
    );

    let ctl = InferClient::connect(&replica_addr).expect("stats client");
    let stats = ctl.stats().expect("stats");
    assert_eq!(stats.requests, total as u64, "replica must have answered every request");
    assert!(stats.sparse_pulls >= 1, "serving must have pulled the model at least once");
    assert!(
        stats.sparse_pulls <= stats.batches,
        "at most one coalesced pull per batch"
    );
    println!(
        "  replica: {} batches (avg {:.2} docs), {} cache hits / {} docs, {} words over {} \
         sparse pulls",
        stats.batches,
        stats.docs as f64 / stats.batches.max(1) as f64,
        stats.cache_hits,
        stats.docs,
        stats.words_pulled,
        stats.sparse_pulls
    );

    // Orderly teardown: replica first (its engine holds the shard
    // connection), then the shards.
    ctl.shutdown().expect("replica shutdown");
    replica.join();
    trainer.shutdown_servers().expect("shard shutdown");
    shard_server.join();

    let json_path =
        std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_serving.json".to_string());
    write_json(
        &json_path,
        smoke,
        clients,
        dims.requests_per_client,
        pool.len(),
        p50_ms,
        p99_ms,
        qps,
        wall_secs,
        &stats,
    );
}
