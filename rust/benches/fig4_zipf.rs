//! Regenerates the paper's Figure 4: top-5000 word frequencies of the
//! (synthetic) ClueWeb12 corpus on log-log axes, plus the fitted Zipf
//! exponent.

use glint_lda::experiments::fig4;

fn main() {
    glint_lda::util::logger::set_level_str("info");
    let scale: f64 = std::env::var("GLINT_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let r = fig4::run(&fig4::Fig4Config { scale, top: 5000, stride: 100 })
        .expect("fig4 run");
    println!(
        "zipf fit over top-5000: log f = {:.2} {:+.3} log r (exponent {:.3})",
        r.intercept, r.slope, -r.slope
    );
    println!("{}", r.report.to_table());
    assert!(
        (-1.6..=-0.7).contains(&r.slope),
        "slope {} not web-like",
        r.slope
    );
}
