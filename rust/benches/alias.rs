//! Alias-table micro-benchmarks: O(K) build and O(1) sampling — the
//! ingredient behind LightLDA's word proposal (paper §3 / Vose [14]).

use glint_lda::lda::alias::AliasTable;
use glint_lda::util::rng::Pcg64;
use glint_lda::util::timer::{bench, fmt_secs};

fn main() {
    let mut rng = Pcg64::new(3);
    println!("{:>8} {:>14} {:>16} {:>18}", "K", "build", "sample", "samples/s");
    for &k in &[16usize, 64, 256, 1024, 4096] {
        let weights: Vec<f64> = (0..k).map(|_| rng.f64() * 10.0 + 0.01).collect();
        let build = bench(3, 20, || AliasTable::new(&weights));
        let table = AliasTable::new(&weights);
        let mut srng = Pcg64::new(9);
        let sample = bench(3, 20, || {
            let mut acc = 0u64;
            for _ in 0..10_000 {
                acc += table.sample(&mut srng) as u64;
            }
            acc
        });
        let per_sample = sample.mean / 10_000.0;
        println!(
            "{k:>8} {:>14} {:>16} {:>18.0}",
            fmt_secs(build.mean),
            fmt_secs(per_sample),
            1.0 / per_sample
        );
    }
    // O(1) check: per-sample cost at K=4096 within 3x of K=16.
    let w16: Vec<f64> = (0..16).map(|i| i as f64 + 1.0).collect();
    let w4096: Vec<f64> = (0..4096).map(|i| (i % 97) as f64 + 1.0).collect();
    let t16 = AliasTable::new(&w16);
    let t4096 = AliasTable::new(&w4096);
    let mut srng = Pcg64::new(10);
    let s16 = bench(3, 30, || (0..10_000).map(|_| t16.sample(&mut srng) as u64).sum::<u64>());
    let mut srng = Pcg64::new(10);
    let s4096 =
        bench(3, 30, || (0..10_000).map(|_| t4096.sample(&mut srng) as u64).sum::<u64>());
    let ratio = s4096.mean / s16.mean;
    println!("\nper-sample cost K=4096 / K=16: {ratio:.2}x (O(1) expectation: ~1)");
    assert!(ratio < 3.0, "sampling should be O(1) in K");
}
