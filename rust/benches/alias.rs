//! Alias-table micro-benchmarks: O(K) build and O(1) sampling — the
//! ingredient behind LightLDA's word proposal (paper §3 / Vose [14]) —
//! plus the Zipf K-scaling contrast between the dense build and the
//! hybrid sparse-mixture build ([`AliasBuilder::build_hybrid`]): tail
//! words must build in O(nnz), not O(K).

use glint_lda::lda::alias::{AliasBuilder, AliasTable, WordProposal};
use glint_lda::util::rng::Pcg64;
use glint_lda::util::timer::{bench, fmt_secs};

fn main() {
    let mut rng = Pcg64::new(3);
    println!("{:>8} {:>14} {:>16} {:>18}", "K", "build", "sample", "samples/s");
    for &k in &[16usize, 64, 256, 1024, 4096] {
        let weights: Vec<f64> = (0..k).map(|_| rng.f64() * 10.0 + 0.01).collect();
        let build = bench(3, 20, || AliasTable::new(&weights));
        let table = AliasTable::new(&weights);
        let mut srng = Pcg64::new(9);
        let sample = bench(3, 20, || {
            let mut acc = 0u64;
            for _ in 0..10_000 {
                acc += table.sample(&mut srng) as u64;
            }
            acc
        });
        let per_sample = sample.mean / 10_000.0;
        println!(
            "{k:>8} {:>14} {:>16} {:>18.0}",
            fmt_secs(build.mean),
            fmt_secs(per_sample),
            1.0 / per_sample
        );
    }
    // O(1) check: per-sample cost at K=4096 within 3x of K=16.
    let w16: Vec<f64> = (0..16).map(|i| i as f64 + 1.0).collect();
    let w4096: Vec<f64> = (0..4096).map(|i| (i % 97) as f64 + 1.0).collect();
    let t16 = AliasTable::new(&w16);
    let t4096 = AliasTable::new(&w4096);
    let mut srng = Pcg64::new(10);
    let s16 = bench(3, 30, || (0..10_000).map(|_| t16.sample(&mut srng) as u64).sum::<u64>());
    let mut srng = Pcg64::new(10);
    let s4096 =
        bench(3, 30, || (0..10_000).map(|_| t4096.sample(&mut srng) as u64).sum::<u64>());
    let ratio = s4096.mean / s16.mean;
    println!("\nper-sample cost K=4096 / K=16: {ratio:.2}x (O(1) expectation: ~1)");
    assert!(ratio < 3.0, "sampling should be O(1) in K");

    // --- Zipf K-scaling: build cost, dense vs hybrid --------------------
    //
    // A Zipf-tail word keeps a small constant number of nonzero topics
    // no matter how large K grows; the hybrid mixture build must track
    // that nnz while the dense build pays the full O(K).
    let beta = 0.01;
    println!("\nZipf K-scaling: tail-word proposal build, dense vs hybrid");
    println!(
        "{:>8} {:>9} {:>14} {:>14} {:>9}",
        "K", "nnz_tail", "dense build", "hybrid build", "speedup"
    );
    let mut builder = AliasBuilder::new();
    let mut last_speedup = 0.0;
    for &k in &[64usize, 1024, 16384] {
        let nnz = 16.min(k / 2);
        let pairs: Vec<(u32, i64)> =
            (0..nnz).map(|i| ((i * (k / nnz)) as u32, 1 + (i % 7) as i64)).collect();
        let mut row = vec![0i64; k];
        for &(c, v) in &pairs {
            row[c as usize] = v;
        }
        let hybrid = bench(3, 30, || {
            let t = builder.build_hybrid(&pairs, k as u32, beta, 2.0);
            std::hint::black_box(t.total_weight())
        });
        let dense = bench(3, 30, || {
            let t = builder.build_dense(&row, beta);
            std::hint::black_box(t.total_weight())
        });
        last_speedup = dense.mean / hybrid.mean;
        println!(
            "{k:>8} {nnz:>9} {:>14} {:>14} {:>8.1}x",
            fmt_secs(dense.mean),
            fmt_secs(hybrid.mean),
            last_speedup
        );
    }
    // The tentpole claim: at web-scale K the tail build must be at
    // least an order of magnitude cheaper than the dense build (the
    // work ratio at K=16384 / nnz=16 is 1024x; 10x leaves a wide noise
    // margin).
    assert!(
        last_speedup > 10.0,
        "hybrid tail build should be >=10x faster than dense at K=16384 \
         (got {last_speedup:.1}x)"
    );
}
