//! End-to-end driver: the scaled "ClueWeb12" run (paper §4, Figure 6).
//!
//! Generates the web-scale analogue corpus (~16k docs, V=16k by default
//! — override with --docs/--vocab/--topics), trains LightLDA over the
//! asynchronous parameter server with all production features enabled
//! (pipelined pulls, push buffering, dense hot-word aggregation,
//! checkpointing), logs the perplexity curve per iteration, and
//! cross-validates the final perplexity on the XLA/Pallas evaluator.
//!
//! ```sh
//! cargo run --release --example train_webscale -- --topics 100 --iters 30
//! ```
//!
//! The run is recorded in EXPERIMENTS.md (§End-to-end).

use std::path::PathBuf;

use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::eval::topics::summarize;
use glint_lda::lda::trainer::{TrainConfig, Trainer};
use glint_lda::runtime::engine::Engine;
use glint_lda::util::cli::Args;
use glint_lda::util::timer::Stopwatch;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1))?;
    glint_lda::util::logger::set_level_str(&args.str_or("log", "info"));

    let num_topics: u32 = args.get_as("topics", 100)?;
    let iterations: u32 = args.get_as("iters", 30)?;
    let ckpt_dir = std::env::temp_dir().join("glint_webscale_ckpt");
    let _ = std::fs::remove_dir_all(&ckpt_dir);

    let corpus = generate(&SynthConfig {
        num_docs: args.get_as("docs", 16_000)?,
        vocab_size: args.get_as("vocab", 16_000)?,
        num_topics: 60,
        avg_doc_len: 90.0,
        zipf_exponent: 1.07,
        ..Default::default()
    });
    println!(
        "corpus: {} docs, {} tokens, V={} | model: K={num_topics} => {} parameters",
        corpus.num_docs(),
        corpus.num_tokens(),
        corpus.vocab_size,
        corpus.vocab_size as u64 * num_topics as u64
    );

    let cfg = TrainConfig {
        num_topics,
        iterations,
        workers: args.get_as("workers", 4)?,
        shards: args.get_as("shards", 8)?,
        eval_every: args.get_as("eval-every", 1)?,
        checkpoint_dir: Some(ckpt_dir.clone()),
        ..TrainConfig::default()
    };
    let clock = Stopwatch::new();
    let mut trainer = Trainer::new(cfg, &corpus)?;
    println!("setup done at t={:.1}s; training...", clock.secs());
    let model = trainer.run(&corpus)?;

    println!("\nloss (perplexity) curve:");
    println!("{}", trainer.report.to_table());

    let final_p = trainer.training_perplexity(&model, &corpus);
    println!("final perplexity (rust evaluator): {final_p:.1}");

    // Cross-check on the AOT XLA/Pallas path if artifacts are built.
    let artifact_dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    match Engine::new(&artifact_dir) {
        Ok(engine) => {
            let sw = Stopwatch::new();
            let counts = trainer.doc_counts();
            let xla_p =
                glint_lda::eval::xla::xla_perplexity(&engine, &model, &corpus, &counts)?;
            println!(
                "final perplexity (xla/pallas evaluator, {}): {xla_p:.1} [{:.2}s]",
                engine.platform(),
                sw.secs()
            );
            let rel = ((final_p - xla_p) / final_p).abs();
            assert!(rel < 1e-3, "evaluators disagree: {final_p} vs {xla_p}");
        }
        Err(e) => println!("xla evaluator skipped: {e}"),
    }

    println!("\nbiggest topics:");
    for line in summarize(&model, &corpus.vocab, 10).into_iter().take(8) {
        println!("  {line}");
    }
    println!(
        "\ntotal wall-clock {:.1}s; PS traffic {:.1} MB pushed; checkpoints in {}",
        clock.secs(),
        trainer.bytes_pushed() as f64 / 1e6,
        ckpt_dir.display()
    );
    println!("train_webscale OK");
    Ok(())
}
