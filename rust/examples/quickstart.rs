//! Quickstart: train a 20-topic model on a small real-text + synthetic
//! mix and print the discovered topics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::corpus::tokenizer::TokenizerConfig;
use glint_lda::corpus::vocab::corpus_from_texts;
use glint_lda::eval::topics::summarize;
use glint_lda::lda::sweep::SamplerParams;
use glint_lda::lda::trainer::{TrainConfig, Trainer};

/// A handful of themed snippets: enough for the real-text pipeline
/// (tokenize → stopwords → stem → frequency-ordered vocab) to produce
/// separable topics.
const SNIPPETS: &[&str] = &[
    "The recipe calls for fresh meat, aromatic spices and a slow cooker. Season the meat with spices.",
    "Grind the spices, marinate the meat overnight, and the recipe rewards patience with flavor.",
    "A good recipe balances spices; cheap cuts of meat become tender in the oven.",
    "Gold rings and diamond necklaces gleamed in the jewelry shop window.",
    "The jeweler set a flawless diamond into a gold ring for the wedding.",
    "Jewelry appraisers weigh gold and grade diamonds under bright light.",
    "The football team scored in the final minute; the crowd roared in the stadium.",
    "A transfer record: the striker joined the club, and the league title race tightened.",
    "The stadium hosts the league final; both teams drilled set pieces all week.",
    "Browsers cache web pages; the crawler indexed millions of documents overnight.",
    "The search engine ranks web documents by relevance and freshness signals.",
    "A distributed crawler fetches pages politely and updates the web index.",
];

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // --- Real-text path ---------------------------------------------------
    let real = corpus_from_texts(SNIPPETS, &TokenizerConfig::default(), 1, 10_000);
    println!(
        "real-text corpus: {} docs, {} tokens, V={} (frequency-ordered: {})",
        real.num_docs(),
        real.num_tokens(),
        real.vocab_size,
        real.is_frequency_ordered()
    );
    let cfg = TrainConfig {
        num_topics: 4,
        iterations: 60,
        workers: 2,
        shards: 2,
        sampler: SamplerParams { block_words: 64, ..Default::default() },
        eval_every: 0,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(cfg, &real)?;
    let model = trainer.run(&real)?;
    println!("\ndiscovered topics (top words):");
    for line in summarize(&model, &real.vocab, 6) {
        println!("  {line}");
    }

    // --- Synthetic path (the scalable workload) ---------------------------
    let synth = generate(&SynthConfig {
        num_docs: 2000,
        vocab_size: 3000,
        num_topics: 20,
        avg_doc_len: 60.0,
        ..Default::default()
    });
    let cfg = TrainConfig {
        num_topics: 20,
        iterations: 15,
        workers: 4,
        shards: 4,
        eval_every: 5,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(cfg, &synth)?;
    let model = trainer.run(&synth)?;
    println!(
        "\nsynthetic corpus perplexity after 15 iterations: {:.1}",
        trainer.training_perplexity(&model, &synth)
    );
    println!("\nquickstart OK");
    Ok(())
}
