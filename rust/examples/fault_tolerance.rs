//! Fault tolerance demo (paper §3.5), both deployment modes:
//!
//! 1. **Single process**: a training run is killed mid-stream; recovery
//!    loads the latest checkpoint and REBUILDS the parameter-server
//!    count tables from the checkpointed topic assignments, then
//!    continues training — and we verify the rebuilt state is exactly
//!    consistent. The run also uses a lossy network (message drops +
//!    duplicates) the whole time, exercising the exactly-once push
//!    protocol under fire.
//! 2. **Cluster**: a coordinator drives two remote workers against TCP
//!    shards; one worker crashes mid-iteration. Heartbeat silence
//!    triggers detection, the partition is reassigned to a standby, the
//!    epoch rolls onto a fresh count table rebuilt from per-partition
//!    checkpoints, and training completes anyway.
//! 3. **Replicated shards**: WAL-backed primaries with backup replicas
//!    tailing their logs; one *shard* (not a worker) is killed
//!    mid-training. The workers' clients fail over, the coordinator
//!    promotes the backup and rolls the epoch, and training converges
//!    on the survivors.
//! 4. **Replication chains under chaos**: depth-2 standby chains, with
//!    a deterministic network-fault plan injected on every TCP round
//!    trip. Shard 0's primary is killed, its promoted successor is
//!    killed too; promotion walks the chain head-ward and the tail is
//!    re-seeded (`ReplSeed`) behind each new head. Snapshot (BSP)
//!    sweeps make the final count table bit-exact, diffed against a
//!    no-fault baseline run.
//! 5. **Planned drain**: a serving head is handed off to its standby
//!    mid-run via the drain protocol — zero epoch rolls, bounded
//!    client retries, nothing acked lost.
//!
//! ```sh
//! cargo run --release --example fault_tolerance
//! # env knobs: SMOKE=1 runs only the replicated-shard scenario;
//! #            SMOKE=chain runs only the chain + drain scenarios
//! #            (GLINT_CHAOS_PLAN / GLINT_CHAOS_SEED pin the chaos);
//! #            DURABILITY_CSV=path writes replica metrics for CI
//! ```

use std::net::SocketAddr;

use glint_lda::cluster::{run_worker, Coordinator, CorpusSpec, WorkerOptions};
use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::lda::checkpoint::PartitionCheckpoint;
use glint_lda::lda::trainer::{TrainConfig, Trainer};
use glint_lda::net::chaos;
use glint_lda::net::tcp::{resolve_addrs, TcpTransport};
use glint_lda::net::FaultPlan;
use glint_lda::ps::client::PsClient;
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::server::{TcpShardServer, ROLE_BACKUP, ROLE_PROMOTED};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let ckpt = std::env::temp_dir().join("glint_ft_demo");
    let _ = std::fs::remove_dir_all(&ckpt);

    let corpus = generate(&SynthConfig {
        num_docs: 2000,
        vocab_size: 3000,
        num_topics: 20,
        avg_doc_len: 60.0,
        ..Default::default()
    });
    match std::env::var("SMOKE").ok().as_deref() {
        // CI's chaos leg: the chain + planned-drain scenarios under a
        // deterministic network-fault plan.
        Some("chain") => {
            chain_demo(&corpus)?;
            drain_demo(&corpus)?;
            println!("fault_tolerance OK");
            return Ok(());
        }
        // CI's durability leg: just the shard-kill scenario.
        Some(_) => {
            replica_demo(&corpus)?;
            println!("fault_tolerance OK");
            return Ok(());
        }
        None => {}
    }
    let cfg = TrainConfig {
        num_topics: 20,
        iterations: 6,
        workers: 3,
        shards: 3,
        eval_every: 0,
        checkpoint_dir: Some(ckpt.clone()),
        // A hostile network: 5% of requests AND 5% of replies vanish,
        // 5% of deliveries are duplicated.
        fault: FaultPlan::lossy(0.05, 0.05),
        ..TrainConfig::default()
    };

    println!("phase 1: train 6 iterations over a lossy network, checkpointing each");
    let mut t1 = Trainer::new(cfg.clone(), &corpus)?;
    let model_before = t1.run(&corpus)?;
    let p_before = t1.training_perplexity(&model_before, &corpus);
    println!("  perplexity at crash point: {p_before:.1}");
    println!("phase 2: simulate total failure (drop trainer + parameter servers)");
    drop(t1);

    println!("phase 3: recover from the latest checkpoint, rebuild count tables");
    let mut cfg2 = cfg;
    cfg2.iterations = 10; // continue for 4 more
    let mut t2 = Trainer::restore(cfg2, &corpus)?;
    println!("  restored at iteration {}", t2.completed_iterations());
    t2.verify_counts()?;
    println!("  rebuilt parameter-server state verified consistent");
    let model_rebuilt = t2.pull_model()?;
    assert_eq!(
        model_rebuilt.n_wk, model_before.n_wk,
        "rebuilt n_wk must equal pre-crash state"
    );
    println!("  rebuilt model identical to pre-crash model");

    println!("phase 4: continue training to iteration 10");
    let model_after = t2.run(&corpus)?;
    let p_after = t2.training_perplexity(&model_after, &corpus);
    println!("  perplexity after recovery + 4 more iterations: {p_after:.1}");
    assert!(p_after <= p_before * 1.02, "training must keep improving");

    let _ = std::fs::remove_dir_all(&ckpt);
    println!("fault_tolerance (single process) OK\n");

    cluster_demo(&corpus)?;
    replica_demo(&corpus)?;
    chain_demo(&corpus)?;
    drain_demo(&corpus)?;
    println!("fault_tolerance OK");
    Ok(())
}

/// The cluster path: worker crash → heartbeat-silence detection →
/// partition reassignment to a standby → epoch rolled onto a fresh
/// count table rebuilt from per-partition checkpoints.
fn cluster_demo(
    corpus: &glint_lda::corpus::dataset::Corpus,
) -> Result<(), Box<dyn std::error::Error>> {
    let ckpt = std::env::temp_dir().join("glint_ft_cluster_demo");
    let _ = std::fs::remove_dir_all(&ckpt);

    println!("cluster phase 1: 2 TCP shards + coordinator + 2 workers (+1 standby)");
    let want: Vec<SocketAddr> = (0..2).map(|_| "127.0.0.1:0".parse().unwrap()).collect();
    // Binding is enough to keep the shard serve loops alive for the demo.
    let _shards = TcpShardServer::bind(PsConfig::with_shards(2), 0, &want)?;
    let shard_addrs: Vec<String> = _shards.addrs().iter().map(|a| a.to_string()).collect();

    let cfg = TrainConfig {
        num_topics: 20,
        iterations: 6,
        workers: 2,
        shards: 2,
        eval_every: 0,
        checkpoint_dir: Some(ckpt.clone()),
        transport: TransportMode::Connect(shard_addrs),
        heartbeat_ms: 100,
        straggler_timeout_ms: 1500,
        ..TrainConfig::default()
    };
    let coordinator = Coordinator::bind("127.0.0.1:0", cfg, corpus, CorpusSpec::Provided)?;
    let join = coordinator.addr().to_string();
    let coord = std::thread::spawn(move || coordinator.run());

    println!("cluster phase 2: one worker will crash right after sweeping iteration 3");
    let mut workers = Vec::new();
    for crash in [Some(3u32), None, None] {
        let opts = WorkerOptions {
            join: join.clone(),
            corpus: Some(corpus.clone()),
            crash_at_iteration: crash,
            ..WorkerOptions::default()
        };
        workers.push(std::thread::spawn(move || run_worker(opts)));
        // Stagger so the crash-rigged worker (spawned first) holds a
        // partition and the last spawn parks as the standby.
        std::thread::sleep(std::time::Duration::from_millis(200));
    }

    let outcome = coord.join().expect("coordinator thread")?;
    let mut crashed = 0;
    for w in workers {
        if w.join().expect("worker thread")?.crashed {
            crashed += 1;
        }
    }
    println!(
        "cluster phase 3: {} crash(es) survived via {} epoch roll(s), {} reassignment(s)",
        crashed, outcome.epochs, outcome.reassignments
    );
    assert_eq!(crashed, 1);
    assert!(outcome.epochs >= 1, "the crash must roll the epoch");
    assert!(outcome.reassignments >= 1, "the lost partition must be reassigned");
    assert_eq!(
        outcome.model.n_k.iter().sum::<i64>(),
        corpus.num_tokens() as i64,
        "rebuilt count table must cover every token exactly once"
    );

    let _ = std::fs::remove_dir_all(&ckpt);
    println!("fault_tolerance (cluster) OK");
    Ok(())
}

/// The replicated-shard path: WAL-backed primaries, backup replicas
/// tailing their committed logs, and a shard killed mid-training. The
/// workers' clients fail over to the backup, the coordinator's probe
/// sees an un-promoted backup answering the shard's route (the
/// dead-primary signal), promotes it, repoints the shard address and
/// rolls the epoch — and training converges on the survivors.
fn replica_demo(
    corpus: &glint_lda::corpus::dataset::Corpus,
) -> Result<(), Box<dyn std::error::Error>> {
    let ckpt = std::env::temp_dir().join("glint_ft_replica_ckpt");
    let wal = std::env::temp_dir().join("glint_ft_replica_wal");
    let _ = std::fs::remove_dir_all(&ckpt);
    let _ = std::fs::remove_dir_all(&wal);

    println!("replica phase 1: 2 WAL-backed primaries + 2 backups + coordinator");
    // Each primary is its own server object so one can die alone.
    let one: Vec<SocketAddr> = vec!["127.0.0.1:0".parse().unwrap()];
    let mut pcfg = PsConfig::with_shards(2);
    pcfg.wal_dir = Some(wal.clone());
    let p0 = TcpShardServer::bind(pcfg.clone(), 0, &one)?;
    let p1 = TcpShardServer::bind(pcfg, 1, &one)?;
    let primary_addrs =
        vec![p0.addrs()[0].to_string(), p1.addrs()[0].to_string()];

    // One process hosts both backup shards, each polling its primary.
    let mut bcfg = PsConfig::with_shards(2);
    bcfg.backup_of = Some(primary_addrs.clone());
    let two: Vec<SocketAddr> = (0..2).map(|_| "127.0.0.1:0".parse().unwrap()).collect();
    let backups = TcpShardServer::bind(bcfg, 0, &two)?;
    let backup_addrs: Vec<String> = backups.addrs().iter().map(|a| a.to_string()).collect();

    let cfg = TrainConfig {
        num_topics: 20,
        iterations: 8,
        workers: 2,
        shards: 2,
        eval_every: 2,
        checkpoint_dir: Some(ckpt.clone()),
        transport: TransportMode::Connect(primary_addrs.clone()),
        backups: backup_addrs,
        heartbeat_ms: 100,
        straggler_timeout_ms: 1500,
        ..TrainConfig::default()
    };
    let coordinator = Coordinator::bind("127.0.0.1:0", cfg, corpus, CorpusSpec::Provided)?;
    let join = coordinator.addr().to_string();
    let coord = std::thread::spawn(move || coordinator.run());

    println!("replica phase 2: workers join; shard 0 dies at iteration 3");
    let mut workers = Vec::new();
    for _ in 0..3 {
        let opts = WorkerOptions {
            join: join.clone(),
            corpus: Some(corpus.clone()),
            ..WorkerOptions::default()
        };
        workers.push(std::thread::spawn(move || run_worker(opts)));
        std::thread::sleep(std::time::Duration::from_millis(200));
    }

    // The assassin: wait until partition 0 has checkpointed iteration 3
    // (training is provably mid-run), then stop shard 0's primary — to
    // every client it looks like a kill -9: the socket goes away and
    // requests start timing out.
    let victim = primary_addrs[0].clone();
    let watch = ckpt.clone();
    let assassin =
        std::thread::spawn(move || -> Result<(), glint_lda::util::error::Error> {
            loop {
                match PartitionCheckpoint::load_latest(&watch, 0) {
                    Ok(Some(c)) if c.inner.iteration >= 3 => break,
                    _ => std::thread::sleep(std::time::Duration::from_millis(50)),
                }
            }
            println!("replica phase 3: killing primary {victim}");
            let resolved = resolve_addrs(&[victim.clone()])?;
            let kcfg = PsConfig {
                shards: 1,
                transport: TransportMode::Connect(vec![victim]),
                ..PsConfig::default()
            };
            let transport = TcpTransport::connect(&resolved);
            let killer = PsClient::connect(&transport, kcfg);
            killer.shutdown_servers()
        });

    let outcome = coord.join().expect("coordinator thread")?;
    assassin.join().expect("assassin thread")?;
    // Failover can (rarely) cost a worker; the standby absorbs that.
    let finished = workers
        .into_iter()
        .filter_map(|w| w.join().expect("worker thread").ok())
        .count();
    assert!(finished >= 2, "at least two workers must finish cleanly");

    println!(
        "replica phase 4: survived via {} promotion(s), {} epoch roll(s)",
        outcome.promotions, outcome.epochs
    );
    assert!(outcome.promotions >= 1, "the shard kill must promote its backup");
    assert!(outcome.epochs >= 1, "promotion must roll the epoch");
    assert_eq!(
        outcome.model.n_k.iter().sum::<i64>(),
        corpus.num_tokens() as i64,
        "rebuilt count table must cover every token exactly once"
    );
    let perplexity = outcome
        .final_perplexity
        .ok_or("no evaluation point produced a perplexity")?;
    assert!(perplexity.is_finite() && perplexity > 1.0, "nonsense perplexity");
    println!("  final training perplexity: {perplexity:.1}");

    if let Ok(csv) = std::env::var("DURABILITY_CSV") {
        let mut out = String::from("metric,value\n");
        out.push_str(&format!("promotions,{}\n", outcome.promotions));
        out.push_str(&format!("epoch_rolls,{}\n", outcome.epochs));
        out.push_str(&format!("reassignments,{}\n", outcome.reassignments));
        out.push_str(&format!("workers_finished,{finished}\n"));
        out.push_str(&format!("final_perplexity,{perplexity:.3}\n"));
        out.push_str(&format!("tokens_covered,{}\n", corpus.num_tokens()));
        std::fs::write(&csv, out)?;
        println!("durability metrics written to {csv}");
    }

    let _ = std::fs::remove_dir_all(&ckpt);
    let _ = std::fs::remove_dir_all(&wal);
    println!("fault_tolerance (replicated shards) OK");
    Ok(())
}

/// Admin client pinned to one replica address (introspection / kills).
fn admin_client(addr: &str) -> Result<PsClient, glint_lda::util::error::Error> {
    let resolved = resolve_addrs(&[addr.to_string()])?;
    let cfg = PsConfig {
        shards: 1,
        transport: TransportMode::Connect(vec![addr.to_string()]),
        ..PsConfig::default()
    };
    Ok(PsClient::connect(&TcpTransport::connect(&resolved), cfg))
}

/// Stop the shard serve loop at `addr` — to every client it looks like
/// a kill -9: the socket goes away and requests start timing out. (The
/// stop signal itself rides the reliable control channel, so it lands
/// even under an installed chaos plan.)
fn kill_shard(addr: &str) -> Result<(), glint_lda::util::error::Error> {
    admin_client(addr)?.shutdown_servers()
}

/// Block until partition 0 has checkpointed `iteration` (training is
/// provably that far along).
fn wait_for_iteration(ckpt: &std::path::Path, iteration: u32) {
    loop {
        match PartitionCheckpoint::load_latest(ckpt, 0) {
            Ok(Some(c)) if c.inner.iteration >= iteration => return,
            _ => std::thread::sleep(std::time::Duration::from_millis(50)),
        }
    }
}

/// The training configuration both chain-demo runs (chaotic and
/// baseline) share: snapshot (BSP) sweeps in lockstep, so the final
/// count table is bit-identical for ANY failure history and the two
/// runs can be diffed.
fn chain_cfg(
    shard_addrs: Vec<String>,
    backups: Vec<String>,
    ckpt: std::path::PathBuf,
) -> TrainConfig {
    TrainConfig {
        num_topics: 20,
        iterations: 8,
        workers: 2,
        shards: 2,
        eval_every: 2,
        checkpoint_dir: Some(ckpt),
        transport: TransportMode::Connect(shard_addrs),
        backups,
        heartbeat_ms: 100,
        straggler_timeout_ms: 1500,
        snapshot: true,
        max_staleness: 0,
        seed: 0xc4a1,
        ..TrainConfig::default()
    }
}

/// The chain path, under deterministic network chaos: a depth-2
/// standby chain behind each WAL-backed primary. Shard 0's primary is
/// killed mid-training; the coordinator promotes the tier-1 standby
/// and re-seeds tier 2 behind it (`ReplSeed`). Then the promoted head
/// is killed too: promotion walks head-ward onto the re-seeded tail
/// and training still converges — with final counts bit-exact against
/// a no-fault baseline run.
fn chain_demo(
    corpus: &glint_lda::corpus::dataset::Corpus,
) -> Result<(), Box<dyn std::error::Error>> {
    let ckpt = std::env::temp_dir().join("glint_ft_chain_ckpt");
    let wal = std::env::temp_dir().join("glint_ft_chain_wal");
    let _ = std::fs::remove_dir_all(&ckpt);
    let _ = std::fs::remove_dir_all(&wal);

    // Deterministic TCP fault injection for everything from here on.
    // Exactly-once pushes make the final counts immune to it, and the
    // install logs a `--chaos-plan ... --chaos-seed ...` line, so any
    // failure below replays bit-exactly. Env vars let CI pin the plan.
    if !chaos::install_from_env() {
        chaos::install(chaos::parse_plan("drop=0.03,dup=0.03")?, 7);
    }

    println!("chain phase 1: 2 WAL primaries, depth-2 standby chains, chaos on the wire");
    let one: Vec<SocketAddr> = vec!["127.0.0.1:0".parse().unwrap()];
    let mut pcfg = PsConfig::with_shards(2);
    pcfg.wal_dir = Some(wal.clone());
    let p0 = TcpShardServer::bind(pcfg.clone(), 0, &one)?;
    let p1 = TcpShardServer::bind(pcfg, 1, &one)?;
    let primary_addrs = vec![p0.addrs()[0].to_string(), p1.addrs()[0].to_string()];

    // Two standby tiers, each a process hosting a replica of both
    // shards. Every standby initially tails its primary; on promotion
    // the coordinator re-points survivors at the new head.
    let two: Vec<SocketAddr> = (0..2).map(|_| "127.0.0.1:0".parse().unwrap()).collect();
    let mut bcfg = PsConfig::with_shards(2);
    bcfg.backup_of = Some(primary_addrs.clone());
    let tier1 = TcpShardServer::bind(bcfg.clone(), 0, &two)?;
    let tier2 = TcpShardServer::bind(bcfg, 0, &two)?;
    // Tier-major: [t1s0, t1s1, t2s0, t2s1].
    let mut backup_addrs: Vec<String> = tier1.addrs().iter().map(|a| a.to_string()).collect();
    backup_addrs.extend(tier2.addrs().iter().map(|a| a.to_string()));

    let cfg = chain_cfg(primary_addrs.clone(), backup_addrs.clone(), ckpt.clone());
    let coordinator = Coordinator::bind("127.0.0.1:0", cfg, corpus, CorpusSpec::Provided)?;
    let join = coordinator.addr().to_string();
    let coord = std::thread::spawn(move || coordinator.run());

    println!("chain phase 2: workers join; shard 0 will lose two heads in sequence");
    let mut workers = Vec::new();
    for _ in 0..3 {
        let opts = WorkerOptions {
            join: join.clone(),
            corpus: Some(corpus.clone()),
            ..WorkerOptions::default()
        };
        workers.push(std::thread::spawn(move || run_worker(opts)));
        std::thread::sleep(std::time::Duration::from_millis(200));
    }

    // The assassin kills shard 0's primary at iteration 3, waits for
    // the chain to heal (tier 1 promoted, tier 2 re-seeded behind it
    // and actively tailing again), then kills the promoted head at
    // iteration 5, leaving only the twice-removed tail.
    let victim1 = primary_addrs[0].clone();
    let victim2 = backup_addrs[0].clone(); // shard 0's tier-1 standby
    let tail = backup_addrs[2].clone(); // shard 0's tier-2 standby
    let watch = ckpt.clone();
    let assassin =
        std::thread::spawn(move || -> Result<u64, String> {
            wait_for_iteration(&watch, 3);
            println!("chain phase 3: kill 1 — primary {victim1} dies");
            kill_shard(&victim1).map_err(|e| e.to_string())?;
            // Heal proof, step 1: tier 1 reports it now serves as the
            // promoted head.
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
            let head = admin_client(&victim2).map_err(|e| e.to_string())?;
            loop {
                if std::time::Instant::now() > deadline {
                    return Err("tier 1 was never promoted after kill 1".into());
                }
                if let Ok(info) = head.shard_info(0) {
                    if info.role == ROLE_PROMOTED {
                        break;
                    }
                }
                std::thread::sleep(std::time::Duration::from_millis(100));
            }
            // Heal proof, step 2: the tail's applied counter grows
            // again with zero lag. Its original upstream is dead and
            // cannot grow it, so growth past promotion means the
            // coordinator re-seeded tier 2 behind the new head and it
            // is actively tailing.
            let observer = admin_client(&tail).map_err(|e| e.to_string())?;
            let mut last = None;
            let lag = loop {
                if std::time::Instant::now() > deadline {
                    return Err("tail was never re-seeded after kill 1".into());
                }
                if let Ok(info) = observer.shard_info(0) {
                    if info.role == ROLE_BACKUP && info.repl_lag == 0 && info.repl_applied > 0 {
                        match last {
                            Some(prev) if info.repl_applied > prev => break info.repl_lag,
                            _ => last = Some(info.repl_applied),
                        }
                    }
                }
                std::thread::sleep(std::time::Duration::from_millis(100));
            };
            println!("  re-seeded tail is tailing the new head again (repl_lag {lag})");
            wait_for_iteration(&watch, 5);
            println!("chain phase 4: kill 2 — promoted head {victim2} dies");
            kill_shard(&victim2).map_err(|e| e.to_string())?;
            Ok(lag)
        });

    let outcome = coord.join().expect("coordinator thread")?;
    let tail_lag = assassin.join().expect("assassin thread")?;
    let finished = workers
        .into_iter()
        .filter_map(|w| w.join().expect("worker thread").ok())
        .count();
    assert!(finished >= 2, "at least two workers must finish cleanly");

    println!(
        "chain phase 5: survived via {} promotions, {} re-seed(s), {} epoch roll(s)",
        outcome.promotions, outcome.reseeds, outcome.epochs
    );
    assert!(outcome.promotions >= 2, "both kills must promote along the chain");
    assert!(outcome.reseeds >= 1, "the tail must be re-seeded behind the new head");
    assert!(outcome.epochs >= 2, "each crash-promotion must roll the epoch");
    assert_eq!(tail_lag, 0, "re-seeded tail must report zero replication lag");
    assert_eq!(
        outcome.model.n_k.iter().sum::<i64>(),
        corpus.num_tokens() as i64,
        "count table must cover every token exactly once"
    );
    let perplexity = outcome
        .final_perplexity
        .ok_or("no evaluation point produced a perplexity")?;
    assert!(perplexity.is_finite() && perplexity > 1.0, "nonsense perplexity");
    println!("  final training perplexity: {perplexity:.1}");

    // The exactness oracle: rerun the same BSP-lockstep schedule on
    // fresh failure-free shards (still under the same chaos plan) and
    // require bit-identical final counts.
    println!("chain phase 6: no-fault baseline for the bit-exactness check");
    let base_ckpt = std::env::temp_dir().join("glint_ft_chain_base");
    let _ = std::fs::remove_dir_all(&base_ckpt);
    let want: Vec<SocketAddr> = (0..2).map(|_| "127.0.0.1:0".parse().unwrap()).collect();
    let base_shards = TcpShardServer::bind(PsConfig::with_shards(2), 0, &want)?;
    let base_addrs: Vec<String> = base_shards.addrs().iter().map(|a| a.to_string()).collect();
    let base_cfg = chain_cfg(base_addrs, Vec::new(), base_ckpt.clone());
    let base_coord = Coordinator::bind("127.0.0.1:0", base_cfg, corpus, CorpusSpec::Provided)?;
    let base_join = base_coord.addr().to_string();
    let bc = std::thread::spawn(move || base_coord.run());
    let mut base_workers = Vec::new();
    for _ in 0..2 {
        let opts = WorkerOptions {
            join: base_join.clone(),
            corpus: Some(corpus.clone()),
            ..WorkerOptions::default()
        };
        base_workers.push(std::thread::spawn(move || run_worker(opts)));
    }
    let baseline = bc.join().expect("baseline coordinator thread")?;
    for w in base_workers {
        w.join().expect("baseline worker thread")?;
    }
    assert_eq!(baseline.epochs, 0, "baseline must run failure-free");
    assert_eq!(
        outcome.model.n_wk, baseline.model.n_wk,
        "double-failover count table diverged from the no-fault baseline"
    );
    assert_eq!(
        outcome.model.n_k, baseline.model.n_k,
        "double-failover topic totals diverged from the no-fault baseline"
    );
    println!("  final count table bit-exact vs the no-fault baseline");

    let _ = std::fs::remove_dir_all(&ckpt);
    let _ = std::fs::remove_dir_all(&base_ckpt);
    let _ = std::fs::remove_dir_all(&wal);
    println!("fault_tolerance (replication chains under chaos) OK");
    Ok(())
}

/// The planned-maintenance path: mid-training, the coordinator drains
/// shard 0's serving head onto its standby. Unlike crash recovery this
/// must cost NO epoch roll — the drain freezes the commit window at a
/// known tip, the standby replicates through it, and clients simply
/// retry their `Unavailable` answers onto the promoted replica.
fn drain_demo(
    corpus: &glint_lda::corpus::dataset::Corpus,
) -> Result<(), Box<dyn std::error::Error>> {
    let ckpt = std::env::temp_dir().join("glint_ft_drain_ckpt");
    let wal = std::env::temp_dir().join("glint_ft_drain_wal");
    let _ = std::fs::remove_dir_all(&ckpt);
    let _ = std::fs::remove_dir_all(&wal);

    println!("drain phase 1: 2 WAL primaries + 1 standby tier + coordinator");
    let one: Vec<SocketAddr> = vec!["127.0.0.1:0".parse().unwrap()];
    let mut pcfg = PsConfig::with_shards(2);
    pcfg.wal_dir = Some(wal.clone());
    let p0 = TcpShardServer::bind(pcfg.clone(), 0, &one)?;
    let p1 = TcpShardServer::bind(pcfg, 1, &one)?;
    let primary_addrs = vec![p0.addrs()[0].to_string(), p1.addrs()[0].to_string()];

    let two: Vec<SocketAddr> = (0..2).map(|_| "127.0.0.1:0".parse().unwrap()).collect();
    let mut bcfg = PsConfig::with_shards(2);
    bcfg.backup_of = Some(primary_addrs.clone());
    let backups = TcpShardServer::bind(bcfg, 0, &two)?;
    let backup_addrs: Vec<String> = backups.addrs().iter().map(|a| a.to_string()).collect();

    let cfg = TrainConfig {
        num_topics: 20,
        iterations: 8,
        workers: 2,
        shards: 2,
        eval_every: 2,
        checkpoint_dir: Some(ckpt.clone()),
        transport: TransportMode::Connect(primary_addrs.clone()),
        backups: backup_addrs,
        heartbeat_ms: 100,
        straggler_timeout_ms: 1500,
        // The planned hand-off: once every partition has completed
        // iteration 3, drain shard 0 onto its standby.
        drain_shard_at: Some((3, 0)),
        ..TrainConfig::default()
    };
    let coordinator = Coordinator::bind("127.0.0.1:0", cfg, corpus, CorpusSpec::Provided)?;
    let join = coordinator.addr().to_string();
    let coord = std::thread::spawn(move || coordinator.run());

    println!("drain phase 2: workers join; shard 0 drains after iteration 3");
    let mut workers = Vec::new();
    for _ in 0..2 {
        let opts = WorkerOptions {
            join: join.clone(),
            corpus: Some(corpus.clone()),
            ..WorkerOptions::default()
        };
        workers.push(std::thread::spawn(move || run_worker(opts)));
        std::thread::sleep(std::time::Duration::from_millis(200));
    }

    let outcome = coord.join().expect("coordinator thread")?;
    for w in workers {
        w.join().expect("worker thread")?;
    }

    println!(
        "drain phase 3: {} hand-off(s), {} epoch roll(s), {} coordinator retry pause(s)",
        outcome.shard_drains, outcome.epochs, outcome.ps_unavailable_retries
    );
    assert_eq!(outcome.shard_drains, 1, "the planned drain must complete");
    assert_eq!(outcome.epochs, 0, "a planned drain must cost zero epoch rolls");
    assert_eq!(outcome.promotions, 0, "no crash promotion may fire during a drain");
    assert!(
        outcome.ps_unavailable_retries < 500,
        "drain hand-off caused an Unavailable storm ({} retry pauses)",
        outcome.ps_unavailable_retries
    );
    assert_eq!(
        outcome.model.n_k.iter().sum::<i64>(),
        corpus.num_tokens() as i64,
        "count table must cover every token exactly once"
    );
    let perplexity = outcome
        .final_perplexity
        .ok_or("no evaluation point produced a perplexity")?;
    assert!(perplexity.is_finite() && perplexity > 1.0, "nonsense perplexity");
    println!("  final training perplexity: {perplexity:.1}");

    let _ = std::fs::remove_dir_all(&ckpt);
    let _ = std::fs::remove_dir_all(&wal);
    println!("fault_tolerance (planned drain) OK");
    Ok(())
}
