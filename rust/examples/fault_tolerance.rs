//! Fault tolerance demo (paper §3.5): a training run is killed
//! mid-stream; recovery loads the latest checkpoint and REBUILDS the
//! parameter-server count tables from the checkpointed topic
//! assignments, then continues training — and we verify the rebuilt
//! state is exactly consistent.
//!
//! The run also uses a lossy network (message drops + duplicates) the
//! whole time, exercising the exactly-once push protocol under fire.
//!
//! ```sh
//! cargo run --release --example fault_tolerance
//! ```

use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::lda::trainer::{TrainConfig, Trainer};
use glint_lda::net::FaultPlan;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let ckpt = std::env::temp_dir().join("glint_ft_demo");
    let _ = std::fs::remove_dir_all(&ckpt);

    let corpus = generate(&SynthConfig {
        num_docs: 2000,
        vocab_size: 3000,
        num_topics: 20,
        avg_doc_len: 60.0,
        ..Default::default()
    });
    let cfg = TrainConfig {
        num_topics: 20,
        iterations: 6,
        workers: 3,
        shards: 3,
        eval_every: 0,
        checkpoint_dir: Some(ckpt.clone()),
        // A hostile network: 5% of requests AND 5% of replies vanish,
        // 5% of deliveries are duplicated.
        fault: FaultPlan::lossy(0.05, 0.05),
        ..TrainConfig::default()
    };

    println!("phase 1: train 6 iterations over a lossy network, checkpointing each");
    let mut t1 = Trainer::new(cfg.clone(), &corpus)?;
    let model_before = t1.run(&corpus)?;
    let p_before = t1.training_perplexity(&model_before, &corpus);
    println!("  perplexity at crash point: {p_before:.1}");
    println!("phase 2: simulate total failure (drop trainer + parameter servers)");
    drop(t1);

    println!("phase 3: recover from the latest checkpoint, rebuild count tables");
    let mut cfg2 = cfg;
    cfg2.iterations = 10; // continue for 4 more
    let mut t2 = Trainer::restore(cfg2, &corpus)?;
    println!("  restored at iteration {}", t2.completed_iterations());
    t2.verify_counts()?;
    println!("  rebuilt parameter-server state verified consistent");
    let model_rebuilt = t2.pull_model()?;
    assert_eq!(
        model_rebuilt.n_wk, model_before.n_wk,
        "rebuilt n_wk must equal pre-crash state"
    );
    println!("  rebuilt model identical to pre-crash model");

    println!("phase 4: continue training to iteration 10");
    let model_after = t2.run(&corpus)?;
    let p_after = t2.training_perplexity(&model_after, &corpus);
    println!("  perplexity after recovery + 4 more iterations: {p_after:.1}");
    assert!(p_after <= p_before * 1.02, "training must keep improving");

    let _ = std::fs::remove_dir_all(&ckpt);
    println!("fault_tolerance OK");
    Ok(())
}
