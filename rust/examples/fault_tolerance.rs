//! Fault tolerance demo (paper §3.5), both deployment modes:
//!
//! 1. **Single process**: a training run is killed mid-stream; recovery
//!    loads the latest checkpoint and REBUILDS the parameter-server
//!    count tables from the checkpointed topic assignments, then
//!    continues training — and we verify the rebuilt state is exactly
//!    consistent. The run also uses a lossy network (message drops +
//!    duplicates) the whole time, exercising the exactly-once push
//!    protocol under fire.
//! 2. **Cluster**: a coordinator drives two remote workers against TCP
//!    shards; one worker crashes mid-iteration. Heartbeat silence
//!    triggers detection, the partition is reassigned to a standby, the
//!    epoch rolls onto a fresh count table rebuilt from per-partition
//!    checkpoints, and training completes anyway.
//! 3. **Replicated shards**: WAL-backed primaries with backup replicas
//!    tailing their logs; one *shard* (not a worker) is killed
//!    mid-training. The workers' clients fail over, the coordinator
//!    promotes the backup and rolls the epoch, and training converges
//!    on the survivors.
//!
//! ```sh
//! cargo run --release --example fault_tolerance
//! # env knobs: SMOKE=1 runs only the replicated-shard scenario;
//! #            DURABILITY_CSV=path writes its metrics for CI
//! ```

use std::net::SocketAddr;

use glint_lda::cluster::{run_worker, Coordinator, CorpusSpec, WorkerOptions};
use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::lda::checkpoint::PartitionCheckpoint;
use glint_lda::lda::trainer::{TrainConfig, Trainer};
use glint_lda::net::tcp::{resolve_addrs, TcpTransport};
use glint_lda::net::FaultPlan;
use glint_lda::ps::client::PsClient;
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::server::TcpShardServer;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let ckpt = std::env::temp_dir().join("glint_ft_demo");
    let _ = std::fs::remove_dir_all(&ckpt);

    let corpus = generate(&SynthConfig {
        num_docs: 2000,
        vocab_size: 3000,
        num_topics: 20,
        avg_doc_len: 60.0,
        ..Default::default()
    });
    if std::env::var("SMOKE").is_ok() {
        // CI's durability leg: just the shard-kill scenario.
        replica_demo(&corpus)?;
        println!("fault_tolerance OK");
        return Ok(());
    }
    let cfg = TrainConfig {
        num_topics: 20,
        iterations: 6,
        workers: 3,
        shards: 3,
        eval_every: 0,
        checkpoint_dir: Some(ckpt.clone()),
        // A hostile network: 5% of requests AND 5% of replies vanish,
        // 5% of deliveries are duplicated.
        fault: FaultPlan::lossy(0.05, 0.05),
        ..TrainConfig::default()
    };

    println!("phase 1: train 6 iterations over a lossy network, checkpointing each");
    let mut t1 = Trainer::new(cfg.clone(), &corpus)?;
    let model_before = t1.run(&corpus)?;
    let p_before = t1.training_perplexity(&model_before, &corpus);
    println!("  perplexity at crash point: {p_before:.1}");
    println!("phase 2: simulate total failure (drop trainer + parameter servers)");
    drop(t1);

    println!("phase 3: recover from the latest checkpoint, rebuild count tables");
    let mut cfg2 = cfg;
    cfg2.iterations = 10; // continue for 4 more
    let mut t2 = Trainer::restore(cfg2, &corpus)?;
    println!("  restored at iteration {}", t2.completed_iterations());
    t2.verify_counts()?;
    println!("  rebuilt parameter-server state verified consistent");
    let model_rebuilt = t2.pull_model()?;
    assert_eq!(
        model_rebuilt.n_wk, model_before.n_wk,
        "rebuilt n_wk must equal pre-crash state"
    );
    println!("  rebuilt model identical to pre-crash model");

    println!("phase 4: continue training to iteration 10");
    let model_after = t2.run(&corpus)?;
    let p_after = t2.training_perplexity(&model_after, &corpus);
    println!("  perplexity after recovery + 4 more iterations: {p_after:.1}");
    assert!(p_after <= p_before * 1.02, "training must keep improving");

    let _ = std::fs::remove_dir_all(&ckpt);
    println!("fault_tolerance (single process) OK\n");

    cluster_demo(&corpus)?;
    replica_demo(&corpus)?;
    println!("fault_tolerance OK");
    Ok(())
}

/// The cluster path: worker crash → heartbeat-silence detection →
/// partition reassignment to a standby → epoch rolled onto a fresh
/// count table rebuilt from per-partition checkpoints.
fn cluster_demo(
    corpus: &glint_lda::corpus::dataset::Corpus,
) -> Result<(), Box<dyn std::error::Error>> {
    let ckpt = std::env::temp_dir().join("glint_ft_cluster_demo");
    let _ = std::fs::remove_dir_all(&ckpt);

    println!("cluster phase 1: 2 TCP shards + coordinator + 2 workers (+1 standby)");
    let want: Vec<SocketAddr> = (0..2).map(|_| "127.0.0.1:0".parse().unwrap()).collect();
    // Binding is enough to keep the shard serve loops alive for the demo.
    let _shards = TcpShardServer::bind(PsConfig::with_shards(2), 0, &want)?;
    let shard_addrs: Vec<String> = _shards.addrs().iter().map(|a| a.to_string()).collect();

    let cfg = TrainConfig {
        num_topics: 20,
        iterations: 6,
        workers: 2,
        shards: 2,
        eval_every: 0,
        checkpoint_dir: Some(ckpt.clone()),
        transport: TransportMode::Connect(shard_addrs),
        heartbeat_ms: 100,
        straggler_timeout_ms: 1500,
        ..TrainConfig::default()
    };
    let coordinator = Coordinator::bind("127.0.0.1:0", cfg, corpus, CorpusSpec::Provided)?;
    let join = coordinator.addr().to_string();
    let coord = std::thread::spawn(move || coordinator.run());

    println!("cluster phase 2: one worker will crash right after sweeping iteration 3");
    let mut workers = Vec::new();
    for crash in [Some(3u32), None, None] {
        let opts = WorkerOptions {
            join: join.clone(),
            corpus: Some(corpus.clone()),
            crash_at_iteration: crash,
        };
        workers.push(std::thread::spawn(move || run_worker(opts)));
        // Stagger so the crash-rigged worker (spawned first) holds a
        // partition and the last spawn parks as the standby.
        std::thread::sleep(std::time::Duration::from_millis(200));
    }

    let outcome = coord.join().expect("coordinator thread")?;
    let mut crashed = 0;
    for w in workers {
        if w.join().expect("worker thread")?.crashed {
            crashed += 1;
        }
    }
    println!(
        "cluster phase 3: {} crash(es) survived via {} epoch roll(s), {} reassignment(s)",
        crashed, outcome.epochs, outcome.reassignments
    );
    assert_eq!(crashed, 1);
    assert!(outcome.epochs >= 1, "the crash must roll the epoch");
    assert!(outcome.reassignments >= 1, "the lost partition must be reassigned");
    assert_eq!(
        outcome.model.n_k.iter().sum::<i64>(),
        corpus.num_tokens() as i64,
        "rebuilt count table must cover every token exactly once"
    );

    let _ = std::fs::remove_dir_all(&ckpt);
    println!("fault_tolerance (cluster) OK");
    Ok(())
}

/// The replicated-shard path: WAL-backed primaries, backup replicas
/// tailing their committed logs, and a shard killed mid-training. The
/// workers' clients fail over to the backup, the coordinator's probe
/// sees an un-promoted backup answering the shard's route (the
/// dead-primary signal), promotes it, repoints the shard address and
/// rolls the epoch — and training converges on the survivors.
fn replica_demo(
    corpus: &glint_lda::corpus::dataset::Corpus,
) -> Result<(), Box<dyn std::error::Error>> {
    let ckpt = std::env::temp_dir().join("glint_ft_replica_ckpt");
    let wal = std::env::temp_dir().join("glint_ft_replica_wal");
    let _ = std::fs::remove_dir_all(&ckpt);
    let _ = std::fs::remove_dir_all(&wal);

    println!("replica phase 1: 2 WAL-backed primaries + 2 backups + coordinator");
    // Each primary is its own server object so one can die alone.
    let one: Vec<SocketAddr> = vec!["127.0.0.1:0".parse().unwrap()];
    let mut pcfg = PsConfig::with_shards(2);
    pcfg.wal_dir = Some(wal.clone());
    let p0 = TcpShardServer::bind(pcfg.clone(), 0, &one)?;
    let p1 = TcpShardServer::bind(pcfg, 1, &one)?;
    let primary_addrs =
        vec![p0.addrs()[0].to_string(), p1.addrs()[0].to_string()];

    // One process hosts both backup shards, each polling its primary.
    let mut bcfg = PsConfig::with_shards(2);
    bcfg.backup_of = Some(primary_addrs.clone());
    let two: Vec<SocketAddr> = (0..2).map(|_| "127.0.0.1:0".parse().unwrap()).collect();
    let backups = TcpShardServer::bind(bcfg, 0, &two)?;
    let backup_addrs: Vec<String> = backups.addrs().iter().map(|a| a.to_string()).collect();

    let cfg = TrainConfig {
        num_topics: 20,
        iterations: 8,
        workers: 2,
        shards: 2,
        eval_every: 2,
        checkpoint_dir: Some(ckpt.clone()),
        transport: TransportMode::Connect(primary_addrs.clone()),
        backups: backup_addrs,
        heartbeat_ms: 100,
        straggler_timeout_ms: 1500,
        ..TrainConfig::default()
    };
    let coordinator = Coordinator::bind("127.0.0.1:0", cfg, corpus, CorpusSpec::Provided)?;
    let join = coordinator.addr().to_string();
    let coord = std::thread::spawn(move || coordinator.run());

    println!("replica phase 2: workers join; shard 0 dies at iteration 3");
    let mut workers = Vec::new();
    for _ in 0..3 {
        let opts = WorkerOptions {
            join: join.clone(),
            corpus: Some(corpus.clone()),
            crash_at_iteration: None,
        };
        workers.push(std::thread::spawn(move || run_worker(opts)));
        std::thread::sleep(std::time::Duration::from_millis(200));
    }

    // The assassin: wait until partition 0 has checkpointed iteration 3
    // (training is provably mid-run), then stop shard 0's primary — to
    // every client it looks like a kill -9: the socket goes away and
    // requests start timing out.
    let victim = primary_addrs[0].clone();
    let watch = ckpt.clone();
    let assassin =
        std::thread::spawn(move || -> Result<(), glint_lda::util::error::Error> {
            loop {
                match PartitionCheckpoint::load_latest(&watch, 0) {
                    Ok(Some(c)) if c.inner.iteration >= 3 => break,
                    _ => std::thread::sleep(std::time::Duration::from_millis(50)),
                }
            }
            println!("replica phase 3: killing primary {victim}");
            let resolved = resolve_addrs(&[victim.clone()])?;
            let kcfg = PsConfig {
                shards: 1,
                transport: TransportMode::Connect(vec![victim]),
                ..PsConfig::default()
            };
            let transport = TcpTransport::connect(&resolved);
            let killer = PsClient::connect(&transport, kcfg);
            killer.shutdown_servers()
        });

    let outcome = coord.join().expect("coordinator thread")?;
    assassin.join().expect("assassin thread")?;
    // Failover can (rarely) cost a worker; the standby absorbs that.
    let finished = workers
        .into_iter()
        .filter_map(|w| w.join().expect("worker thread").ok())
        .count();
    assert!(finished >= 2, "at least two workers must finish cleanly");

    println!(
        "replica phase 4: survived via {} promotion(s), {} epoch roll(s)",
        outcome.promotions, outcome.epochs
    );
    assert!(outcome.promotions >= 1, "the shard kill must promote its backup");
    assert!(outcome.epochs >= 1, "promotion must roll the epoch");
    assert_eq!(
        outcome.model.n_k.iter().sum::<i64>(),
        corpus.num_tokens() as i64,
        "rebuilt count table must cover every token exactly once"
    );
    let perplexity = outcome
        .final_perplexity
        .ok_or("no evaluation point produced a perplexity")?;
    assert!(perplexity.is_finite() && perplexity > 1.0, "nonsense perplexity");
    println!("  final training perplexity: {perplexity:.1}");

    if let Ok(csv) = std::env::var("DURABILITY_CSV") {
        let mut out = String::from("metric,value\n");
        out.push_str(&format!("promotions,{}\n", outcome.promotions));
        out.push_str(&format!("epoch_rolls,{}\n", outcome.epochs));
        out.push_str(&format!("reassignments,{}\n", outcome.reassignments));
        out.push_str(&format!("workers_finished,{finished}\n"));
        out.push_str(&format!("final_perplexity,{perplexity:.3}\n"));
        out.push_str(&format!("tokens_covered,{}\n", corpus.num_tokens()));
        std::fs::write(&csv, out)?;
        println!("durability metrics written to {csv}");
    }

    let _ = std::fs::remove_dir_all(&ckpt);
    let _ = std::fs::remove_dir_all(&wal);
    println!("fault_tolerance (replicated shards) OK");
    Ok(())
}
