//! Cluster smoke run: 1 coordinator + 2 workers + 2 TCP shard servers,
//! all in-process (the exact topology of a multi-machine deployment,
//! minus the machines), trained to completion on a small synthetic
//! corpus. The per-iteration aggregate metrics (tokens/sec, perplexity
//! at evaluation points, parameter-server health) are written as a CSV
//! for CI to archive.
//!
//! ```sh
//! cargo run --release --example cluster_smoke
//! # env knobs: CLUSTER_CSV=path (default CLUSTER_smoke_metrics.csv)
//! ```

use std::net::SocketAddr;

use glint_lda::cluster::{run_worker, Coordinator, CorpusSpec, WorkerOptions};
use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::lda::sweep::SamplerParams;
use glint_lda::lda::trainer::TrainConfig;
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::server::TcpShardServer;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let corpus = generate(&SynthConfig {
        num_docs: 600,
        vocab_size: 1500,
        num_topics: 10,
        avg_doc_len: 50.0,
        seed: 0x5307e,
        ..Default::default()
    });

    // 2 parameter-server shards on loopback TCP.
    let want: Vec<SocketAddr> = (0..2).map(|_| "127.0.0.1:0".parse().unwrap()).collect();
    let shards = TcpShardServer::bind(PsConfig::with_shards(2), 0, &want)?;
    let shard_addrs: Vec<String> = shards.addrs().iter().map(|a| a.to_string()).collect();
    println!("shards up on {shard_addrs:?}");

    let cfg = TrainConfig {
        num_topics: 10,
        iterations: 8,
        workers: 2,
        shards: 2,
        sampler: SamplerParams {
            block_words: 256,
            buffer_cap: 2000,
            dense_top_words: 50,
            ..Default::default()
        },
        eval_every: 2,
        transport: TransportMode::Connect(shard_addrs),
        heartbeat_ms: 200,
        ..TrainConfig::default()
    };

    let coordinator = Coordinator::bind("127.0.0.1:0", cfg, &corpus, CorpusSpec::Provided)?;
    let join_addr = coordinator.addr().to_string();
    println!("coordinator up on {join_addr}");
    let coord = std::thread::spawn(move || coordinator.run());

    let mut workers = Vec::new();
    for i in 0..2 {
        let opts = WorkerOptions {
            join: join_addr.clone(),
            corpus: Some(corpus.clone()),
            ..WorkerOptions::default()
        };
        workers.push(
            std::thread::Builder::new()
                .name(format!("smoke-worker-{i}"))
                .spawn(move || run_worker(opts))?,
        );
    }

    let outcome = coord.join().expect("coordinator thread")?;
    for w in workers {
        let summary = w.join().expect("worker thread")?;
        println!("worker {} completed {} sweeps", summary.worker_id, summary.sweeps);
    }

    println!("{}", outcome.report.to_table());
    let perplexity = outcome
        .final_perplexity
        .ok_or("no evaluation point produced a perplexity")?;
    println!("final training perplexity: {perplexity:.1}");
    assert!(perplexity.is_finite() && perplexity > 1.0, "nonsense perplexity");
    assert_eq!(outcome.epochs, 0, "smoke run must not trip failure recovery");
    assert_eq!(outcome.report.len(), 8, "one aggregate row per iteration");

    let csv = std::env::var("CLUSTER_CSV").unwrap_or_else(|_| "CLUSTER_smoke_metrics.csv".into());
    std::fs::write(&csv, outcome.report.to_csv())?;
    println!("metrics written to {csv}");
    println!("cluster_smoke OK");
    Ok(())
}
