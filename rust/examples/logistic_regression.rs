//! The parameter server reused for a different algorithm: sparse
//! logistic regression with asynchronous SGD (the paper's §5 future-work
//! direction, and the workload of Li et al.'s original parameter-server
//! paper [7]).
//!
//! A sparse synthetic classification problem is trained by several
//! workers in parallel: each prefetches the weight coordinates the
//! *next* minibatch touches while computing the current gradient
//! (asynchronous pull tickets), and sends updates as fire-and-forget
//! push tickets that are barriered once per epoch with `flush()` —
//! exactly the ticket API the LDA trainer uses, demonstrating the PS is
//! a general substrate and that asynchronous SGD tolerates the
//! staleness (Li et al.'s model).
//!
//! ```sh
//! cargo run --release --example logistic_regression
//! ```

use glint_lda::net::FaultPlan;
use glint_lda::ps::client::{BigVector, PsClient};
use glint_lda::ps::config::PsConfig;
use glint_lda::ps::server::ServerGroup;
use glint_lda::util::rng::Pcg64;

/// Sparse example: (feature indices, values), label in {-1, +1}.
struct Example {
    idx: Vec<u64>,
    val: Vec<f32>,
    y: f32,
}

fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

fn make_truth(dim: u64, rng: &mut Pcg64) -> Vec<f32> {
    // Ground-truth sparse weight vector.
    let mut w_true = vec![0f32; dim as usize];
    for w in w_true.iter_mut().take(dim as usize / 4) {
        *w = rng.normal() as f32;
    }
    w_true
}

fn make_data(n: usize, dim: u64, nnz: usize, w_true: &[f32], rng: &mut Pcg64) -> Vec<Example> {
    let mut examples = Vec::with_capacity(n);
    for _ in 0..n {
        let mut idx: Vec<u64> = (0..nnz).map(|_| rng.below(dim as usize) as u64).collect();
        idx.sort_unstable();
        idx.dedup();
        let val: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
        let z: f32 = idx.iter().zip(&val).map(|(&i, &v)| w_true[i as usize] * v).sum();
        // Mostly-separable labels with a little sigmoid noise.
        let y = if rng.f64() < sigmoid(3.0 * z) as f64 { 1.0 } else { -1.0 };
        examples.push(Example { idx, val, y });
    }
    examples
}

fn accuracy(examples: &[Example], w: &[f32]) -> f64 {
    let correct = examples
        .iter()
        .filter(|e| {
            let z: f32 = e.idx.iter().zip(&e.val).map(|(&i, &v)| w[i as usize] * v).sum();
            (z >= 0.0) == (e.y > 0.0)
        })
        .count();
    correct as f64 / examples.len() as f64
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dim: u64 = 2_000;
    let mut rng = Pcg64::new(42);
    let w_true = make_truth(dim, &mut rng);
    let train = make_data(8000, dim, 20, &w_true, &mut rng);
    let test = make_data(2000, dim, 20, &w_true, &mut rng);

    // Parameter server holds the weight vector.
    let ps_cfg = PsConfig::with_shards(4);
    let group = ServerGroup::start(ps_cfg.clone(), FaultPlan::reliable(), 7);
    let client = PsClient::connect(&group.transport(), ps_cfg);
    let weights: BigVector<f32> = client.vector(dim)?;

    let epochs = 5;
    let workers = 4;
    let lr = 0.5f32;

    // The coordinates a minibatch touches (sorted, deduplicated).
    let touched_of = |batch: &[&Example]| {
        let mut touched: Vec<u64> = batch.iter().flat_map(|e| e.idx.iter().copied()).collect();
        touched.sort_unstable();
        touched.dedup();
        touched
    };

    for epoch in 0..epochs {
        std::thread::scope(|scope| {
            for t in 0..workers {
                let weights = weights.clone();
                let touched_of = &touched_of;
                let chunk: Vec<&Example> =
                    train.iter().skip(t).step_by(workers).collect();
                scope.spawn(move || {
                    let batches: Vec<&[&Example]> = chunk.chunks(32).collect();
                    if batches.is_empty() {
                        return;
                    }
                    // Prefetch the first batch's coordinates, then keep
                    // one pull ticket in flight ahead of the compute.
                    let first = touched_of(batches[0]);
                    let first_ticket = weights.pull_async(&first);
                    let mut pending = Some((first, first_ticket));
                    for (b, batch) in batches.iter().enumerate() {
                        let (here, ticket) = pending.take().expect("ticket");
                        let w = ticket.wait().expect("pull");
                        if let Some(next) = batches.get(b + 1) {
                            let coords = touched_of(next);
                            let ticket = weights.pull_async(&coords);
                            pending = Some((coords, ticket));
                        }
                        let at = |i: u64| {
                            w[here.binary_search(&i).unwrap()]
                        };
                        // Accumulate sparse gradient.
                        let mut grad = vec![0f32; here.len()];
                        for e in *batch {
                            let z: f32 =
                                e.idx.iter().zip(&e.val).map(|(&i, &v)| at(i) * v).sum();
                            // dL/dz for logistic loss with labels ±1.
                            let g = -e.y * (1.0 - sigmoid(e.y * z));
                            for (&i, &v) in e.idx.iter().zip(&e.val) {
                                grad[here.binary_search(&i).unwrap()] += g * v;
                            }
                        }
                        let scale = -lr / batch.len() as f32;
                        let deltas: Vec<f32> = grad.iter().map(|&g| g * scale).collect();
                        // Fire-and-forget; the epoch-end flush barriers.
                        let _ = weights.push_async(&here, &deltas);
                    }
                });
            }
        });
        // Epoch barrier: every fire-and-forget push has landed (and any
        // push error surfaces) before evaluation reads the weights.
        client.flush()?;
        let w = weights.pull_all()?;
        println!(
            "epoch {epoch}: train acc {:.3}, test acc {:.3}",
            accuracy(&train, &w),
            accuracy(&test, &w)
        );
    }
    let w = weights.pull_all()?;
    let final_acc = accuracy(&test, &w);
    println!("final test accuracy: {final_acc:.3}");
    assert!(final_acc > 0.75, "PS-trained LR should clearly beat chance");
    println!("logistic_regression OK");
    Ok(())
}
