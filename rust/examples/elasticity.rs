//! Elastic membership demo: one training run that scales 2 → 8 → 3
//! workers mid-flight, entirely through the consistent-hash ring.
//!
//! Phase A starts 2 workers over 16 micro-partitions. Once iteration 3
//! has checkpointed, 6 more workers join (phase B): the ring rebalances
//! and every partition moves warm — checkpoint handoff, no re-push, no
//! epoch roll. Five of the joiners then drain after a fixed number of
//! sweeps (phase C), handing their partitions back at sweep boundaries.
//!
//! The run uses snapshot (BSP) sweeps with a staleness bound of 0, so
//! the final count table is bit-for-bit identical to a second,
//! static-membership baseline run over the same corpus, seed and
//! partitioning — that equality is asserted, along with zero epoch
//! rolls and tokens/sec strictly increasing after the 2 → 8 rebalance.
//!
//! ```sh
//! cargo run --release --example elasticity
//! # env knobs:
//! #   ELASTICITY_CSV=path    per-iteration metrics   (default ELASTICITY_metrics.csv)
//! #   ELASTICITY_BENCH=path  measured bench JSON     (default BENCH_elasticity.json)
//! ```

use std::net::SocketAddr;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use glint_lda::cluster::{
    run_worker, ClusterOutcome, Coordinator, CorpusSpec, WorkerOptions, WorkerSummary,
};
use glint_lda::corpus::dataset::Corpus;
use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::lda::checkpoint::PartitionCheckpoint;
use glint_lda::lda::sweep::SamplerParams;
use glint_lda::lda::trainer::TrainConfig;
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::server::TcpShardServer;

/// 2 workers x partition_factor 8 = 16 fixed micro-partitions.
const PARTITION_FACTOR: usize = 8;
const PARTITIONS: usize = 2 * PARTITION_FACTOR;
const ITERATIONS: u32 = 18;
/// Joiners arrive once this iteration has checkpointed.
const JOIN_AT: u32 = 3;
/// Sweeps a draining joiner completes before asking to leave.
const DRAIN_AFTER: u32 = 8;
/// Artificial per-sweep cost so tokens/sec tracks the member count
/// instead of scheduler noise.
const SWEEP_DELAY_MS: u64 = 25;

fn scratch_dir(tag: &str) -> std::io::Result<PathBuf> {
    let dir = std::env::temp_dir().join(format!("glint-elasticity-{tag}-{}", std::process::id()));
    // A stale directory from an earlier run would satisfy the join
    // trigger (and warm loads) with the wrong data.
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

fn bind_shards() -> Result<(TcpShardServer, Vec<String>), Box<dyn std::error::Error>> {
    let want: Vec<SocketAddr> = (0..2).map(|_| "127.0.0.1:0".parse().unwrap()).collect();
    let shards = TcpShardServer::bind(PsConfig::with_shards(2), 0, &want)?;
    let addrs: Vec<String> = shards.addrs().iter().map(|a| a.to_string()).collect();
    Ok((shards, addrs))
}

fn train_cfg(shard_addrs: Vec<String>, checkpoint_dir: PathBuf, elastic: bool) -> TrainConfig {
    TrainConfig {
        num_topics: 8,
        iterations: ITERATIONS,
        workers: 2,
        shards: 2,
        partition_factor: PARTITION_FACTOR,
        elastic,
        snapshot: true,
        max_staleness: 0,
        sampler: SamplerParams {
            block_words: 256,
            buffer_cap: 2000,
            dense_top_words: 50,
            ..Default::default()
        },
        eval_every: 0,
        transport: TransportMode::Connect(shard_addrs),
        heartbeat_ms: 100,
        straggler_timeout_ms: 60_000,
        checkpoint_dir: Some(checkpoint_dir),
        keep_checkpoints: 0,
        seed: 0xe1a5,
        ..TrainConfig::default()
    }
}

fn spawn_worker(
    name: String,
    join: String,
    corpus: &Corpus,
    drain_after: Option<u32>,
    sweep_delay_ms: u64,
) -> std::io::Result<std::thread::JoinHandle<glint_lda::Result<WorkerSummary>>> {
    let opts = WorkerOptions {
        join,
        corpus: Some(corpus.clone()),
        drain_after,
        sweep_delay_ms,
        ..WorkerOptions::default()
    };
    std::thread::Builder::new().name(name).spawn(move || run_worker(opts))
}

/// Static-membership reference run: same corpus, seed, partitioning and
/// snapshot discipline, fixed 2 workers throughout.
fn run_baseline(corpus: &Corpus) -> Result<ClusterOutcome, Box<dyn std::error::Error>> {
    let (_shards, shard_addrs) = bind_shards()?;
    let cfg = train_cfg(shard_addrs, scratch_dir("baseline")?, false);
    let coordinator = Coordinator::bind("127.0.0.1:0", cfg, corpus, CorpusSpec::Provided)?;
    let join_addr = coordinator.addr().to_string();
    let coord = std::thread::spawn(move || coordinator.run());
    let mut workers = Vec::new();
    for i in 0..2 {
        workers.push(spawn_worker(
            format!("baseline-worker-{i}"),
            join_addr.clone(),
            corpus,
            None,
            0,
        )?);
    }
    let outcome = coord.join().expect("baseline coordinator thread")?;
    for w in workers {
        w.join().expect("baseline worker thread")?;
    }
    Ok(outcome)
}

/// Mean of `tokens_per_sec` over rows whose `members` column satisfies
/// `pred`. `None` when no row matches.
fn phase_tokens_per_sec(outcome: &ClusterOutcome, pred: impl Fn(f64) -> bool) -> Option<f64> {
    let picked: Vec<f64> = outcome
        .report
        .rows()
        .iter()
        .filter(|r| r.get("members").is_some_and(&pred))
        .filter_map(|r| r.get("tokens_per_sec"))
        .collect();
    if picked.is_empty() {
        None
    } else {
        Some(picked.iter().sum::<f64>() / picked.len() as f64)
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let smoke = std::env::var("SMOKE").map(|v| v == "1").unwrap_or(false);
    let corpus = generate(&SynthConfig {
        num_docs: 480,
        vocab_size: 1200,
        num_topics: 8,
        avg_doc_len: 40.0,
        seed: 0xe1a5,
        ..Default::default()
    });

    // ---- Elastic run: 2 -> 8 -> 3 workers on the ring. ----
    let (_shards, shard_addrs) = bind_shards()?;
    let ckpt_dir = scratch_dir("elastic")?;
    let cfg = train_cfg(shard_addrs, ckpt_dir.clone(), true);
    let coordinator = Coordinator::bind("127.0.0.1:0", cfg, &corpus, CorpusSpec::Provided)?;
    let join_addr = coordinator.addr().to_string();
    println!("coordinator up on {join_addr} ({PARTITIONS} partitions, {ITERATIONS} iterations)");
    let coord = std::thread::spawn(move || coordinator.run());

    let mut workers = Vec::new();
    for i in 0..2 {
        workers.push(spawn_worker(
            format!("elastic-worker-{i}"),
            join_addr.clone(),
            &corpus,
            None,
            SWEEP_DELAY_MS,
        )?);
    }

    // Phase B trigger: partition 0 has checkpointed iteration JOIN_AT
    // (keep_checkpoints = 0, so the marker file is never pruned).
    let marker = PartitionCheckpoint::path_for(&ckpt_dir, 0, JOIN_AT);
    let deadline = Instant::now() + Duration::from_secs(120);
    while !marker.exists() {
        assert!(!coord.is_finished(), "run finished before the join trigger");
        assert!(Instant::now() < deadline, "join trigger never appeared: {marker:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
    println!("iteration {JOIN_AT} checkpointed; scaling out to 8 workers");
    for i in 0..6 {
        // Five of the six joiners drain again after DRAIN_AFTER sweeps,
        // taking phase C down to 3 workers.
        let drain_after = if i < 5 { Some(DRAIN_AFTER) } else { None };
        workers.push(spawn_worker(
            format!("elastic-joiner-{i}"),
            join_addr.clone(),
            &corpus,
            drain_after,
            SWEEP_DELAY_MS,
        )?);
    }

    let outcome = coord.join().expect("elastic coordinator thread")?;
    let mut summaries = Vec::new();
    for w in workers {
        summaries.push(w.join().expect("elastic worker thread")?);
    }
    println!("{}", outcome.report.to_table());

    // ---- Elasticity assertions. ----
    assert_eq!(outcome.epochs, 0, "joins and drains must not roll the epoch");
    let drained = summaries.iter().filter(|s| s.drained).count();
    assert_eq!(drained, 5, "five joiners asked to drain");
    assert_eq!(outcome.counters.drain_count, 5, "coordinator saw five drains");
    assert!(outcome.counters.rebalances >= 1, "the 2->8 join must rebalance the ring");
    assert!(outcome.counters.moved_partitions >= 1, "rebalancing moves partitions warm");
    let rows = outcome.report.rows();
    let last_members = rows.last().and_then(|r| r.get("members"));
    assert_eq!(last_members, Some(3.0), "run must finish with 3 members");

    let tps_a = phase_tokens_per_sec(&outcome, |m| m <= 2.0)
        .expect("no rows at 2 members: joiners arrived too early");
    let tps_b = phase_tokens_per_sec(&outcome, |m| m >= 7.0)
        .expect("no rows at 8 members: drains fired before scale-out settled");
    let tps_c = phase_tokens_per_sec(&outcome, |m| m == 3.0).unwrap_or(0.0);
    println!(
        "tokens/sec by phase: A(2 workers) {tps_a:.0}  B(8 workers) {tps_b:.0}  \
         C(3 workers) {tps_c:.0}"
    );
    assert!(
        tps_b > tps_a,
        "throughput must rise after the 2->8 rebalance ({tps_b:.0} <= {tps_a:.0})"
    );

    // Rebalance pause: iterations spent between the stable phases
    // while partitions were still in flight to their new owners.
    let rebalance_pause_secs: f64 = rows
        .iter()
        .filter(|r| r.get("members").is_some_and(|m| m > 2.0 && m < 7.0))
        .filter_map(|r| r.get("seconds"))
        .sum();
    let moved_checkpoint_bytes: u64 = summaries.iter().map(|s| s.warm_bytes).sum();
    println!(
        "rebalance pause {rebalance_pause_secs:.3}s, moved checkpoint bytes \
         {moved_checkpoint_bytes}, moved partitions {}",
        outcome.counters.moved_partitions
    );

    // ---- Exactness vs a static-membership baseline. ----
    println!("running static 2-worker baseline for the exactness check");
    let baseline = run_baseline(&corpus)?;
    assert_eq!(baseline.epochs, 0, "baseline must run failure-free");
    assert_eq!(
        outcome.model.n_wk, baseline.model.n_wk,
        "elastic count table diverged from the static baseline"
    );
    assert_eq!(
        outcome.model.n_k, baseline.model.n_k,
        "elastic topic totals diverged from the static baseline"
    );
    println!("final count table exactly matches the static baseline");

    let csv = std::env::var("ELASTICITY_CSV").unwrap_or_else(|_| "ELASTICITY_metrics.csv".into());
    std::fs::write(&csv, outcome.report.to_csv())?;
    println!("metrics written to {csv}");

    let bench =
        std::env::var("ELASTICITY_BENCH").unwrap_or_else(|_| "BENCH_elasticity.json".into());
    let json = format!(
        "{{\n  \"bench\": \"elasticity\",\n  \"source\": \"measured: cargo run --release \
         --example elasticity\",\n  \"smoke\": {smoke},\n  \"partitions\": {PARTITIONS},\n  \
         \"iterations\": {ITERATIONS},\n  \"phase_a_workers\": 2,\n  \"phase_b_workers\": 8,\n  \
         \"phase_c_workers\": 3,\n  \"phase_a_tokens_per_sec\": {tps_a:.1},\n  \
         \"phase_b_tokens_per_sec\": {tps_b:.1},\n  \"phase_c_tokens_per_sec\": {tps_c:.1},\n  \
         \"rebalance_pause_secs\": {rebalance_pause_secs:.3},\n  \"moved_checkpoint_bytes\": \
         {moved_checkpoint_bytes},\n  \"moved_partitions\": {},\n  \"rebalances\": {},\n  \
         \"drain_count\": {},\n  \"epochs\": 0,\n  \"exact_match_vs_static\": true\n}}\n",
        outcome.counters.moved_partitions,
        outcome.counters.rebalances,
        outcome.counters.drain_count,
    );
    std::fs::write(&bench, json)?;
    println!("bench written to {bench}");
    println!("elasticity OK");
    Ok(())
}
